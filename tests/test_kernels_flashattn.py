"""Flash-attention Pallas kernel vs the jnp oracle, swept over shapes,
dtypes, and masking modes (interpret mode on CPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flashattn import flash_attention

CASES = [
    # (B, H, Sq, Sk, D, bq, bk)
    (1, 2, 64, 64, 32, 32, 32),
    (2, 3, 100, 100, 32, 32, 32),     # padded tiles
    (1, 1, 128, 256, 64, 64, 64),     # cross lengths
    (1, 2, 33, 65, 16, 16, 16),
]


@pytest.mark.parametrize("case", CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal,window", [(True, None), (True, 24),
                                           (False, None)])
def test_flash_matches_oracle(case, dtype, causal, window):
    B, H, Sq, Sk, D, bq, bk = case
    key = jax.random.PRNGKey(B * 7 + Sq)
    q = (jax.random.normal(key, (B, H, Sq, D)) * 0.5).astype(dtype)
    k = (jax.random.normal(jax.random.fold_in(key, 1),
                           (B, H, Sk, D)) * 0.5).astype(dtype)
    v = (jax.random.normal(jax.random.fold_in(key, 2),
                           (B, H, Sk, D)) * 0.5).astype(dtype)
    if not causal and Sq != Sk:
        pytest.skip("oracle aligns positions; enough coverage elsewhere")
    out = flash_attention(q, k, v, causal=causal, window=window, bq=bq,
                          bk=bk, interpret=True)
    want = ref.flash_attention(q, k, v, causal=causal, window=window)
    tol = 2e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_flash_matches_model_chunked_sdpa():
    """The kernel and the pure-JAX online-softmax path agree."""
    from repro.models.attention import chunked_sdpa
    key = jax.random.PRNGKey(0)
    B, H, S, D = 2, 2, 96, 32
    q = jax.random.normal(key, (B, H, S, D))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, H, S, D))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, H, S, D))
    a = flash_attention(q, k, v, causal=True, bq=32, bk=32, interpret=True)
    b = chunked_sdpa(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                     v.transpose(0, 2, 1, 3), causal=True,
                     kv_chunk=32).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-5,
                               atol=2e-5)
