"""Model-zoo correctness: decode == full forward (the KV-cache invariant),
SSD chunked == naive recurrence, RG-LRU scan == stepwise, MoE == dense
oracle at loose capacity."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.configs.base import MoEConfig, SSMConfig
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib
from repro.models.transformer import Batch, Model

CONSISTENCY_ARCHS = ["yi-6b", "chatglm3-6b", "minicpm3-4b", "mamba2-2.7b",
                     "recurrentgemma-2b", "whisper-small",
                     "llava-next-mistral-7b", "deepseek-7b"]


def _inputs(cfg, key, B, S):
    kw = {}
    if cfg.vlm_img_tokens:
        kw["img_embeds"] = jax.random.normal(
            key, (B, cfg.vlm_img_tokens, cfg.vlm_d_vision))
    if cfg.encoder is not None:
        kw["frame_embeds"] = jax.random.normal(
            key, (B, cfg.encoder.n_frames, cfg.encoder.d_input))
    return kw


@pytest.mark.parametrize("arch", CONSISTENCY_ARCHS)
def test_decode_matches_forward(arch):
    cfg = registry.get_smoke_config(arch)
    m = Model(cfg)
    key = jax.random.PRNGKey(7)
    params = m.init(key)
    B, S = 2, 20
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab)
    kw = _inputs(cfg, key, B, S)
    full = m.forward(params, Batch(tokens=tokens, **kw))
    # prefill returns the TOTAL consumed length (image tokens included for
    # VLMs) -- decode must continue from there
    logits_p, cache, pos = m.prefill(params, Batch(tokens=tokens[:, :S - 1],
                                                   **kw), max_seq=S + 12)
    logits_d, _ = m.decode_step(params, cache, tokens[:, S - 1:S],
                                jnp.int32(pos))
    ref = full[:, -1, :]
    rel = float(jnp.max(jnp.abs(logits_d - ref))) / (
        float(jnp.max(jnp.abs(ref))) + 1e-9)
    assert rel < 2e-2, (arch, rel)


@pytest.mark.parametrize("arch", ["arctic-480b", "grok-1-314b"])
def test_moe_decode_matches_forward_loose_capacity(arch):
    cfg = registry.get_smoke_config(arch)
    cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
        cfg.moe, capacity_factor=8.0))
    m = Model(cfg)
    key = jax.random.PRNGKey(7)
    params = m.init(key)
    B, S = 2, 16
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab)
    full = m.forward(params, Batch(tokens=tokens))
    _, cache, _ = m.prefill(params, Batch(tokens=tokens[:, :S - 1]),
                            max_seq=S + 4)
    logits_d, _ = m.decode_step(params, cache, tokens[:, S - 1:S],
                                jnp.int32(S - 1))
    ref = full[:, -1, :]
    rel = float(jnp.max(jnp.abs(logits_d - ref))) / float(
        jnp.max(jnp.abs(ref)))
    assert rel < 2e-2, (arch, rel)


def test_sliding_window_ring_buffer_decode():
    """Dense arch + window override: decoding past the window must agree with
    a full forward restricted by the same window mask."""
    cfg = registry.get_smoke_config("yi-6b")
    m = Model(cfg)
    key = jax.random.PRNGKey(3)
    params = m.init(key)
    B, S, W = 1, 24, 8
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab)
    full = m.forward(params, Batch(tokens=tokens), window_override=W)
    _, cache, _ = m.prefill(params, Batch(tokens=tokens[:, :S - 4]),
                            max_seq=S + 4, window_override=W)
    logits = None
    for i in range(4):
        logits, cache = m.decode_step(params, cache, tokens[:, S - 4 + i:
                                                            S - 3 + i],
                                      jnp.int32(S - 4 + i),
                                      window_override=W)
    ref = full[:, -1, :]
    rel = float(jnp.max(jnp.abs(logits - ref))) / float(jnp.max(jnp.abs(ref)))
    assert rel < 2e-2, rel


def test_ssd_chunked_matches_naive_recurrence():
    """The chunked SSD algorithm == the literal per-step recurrence."""
    B, S, H, P, N = 2, 37, 4, 8, 16
    key = jax.random.PRNGKey(0)
    s = SSMConfig(d_state=N, head_dim=P, chunk=16, n_groups=1)
    xdt = jax.random.normal(key, (B, S, H, P)) * 0.3
    a = -jnp.abs(jax.random.normal(jax.random.fold_in(key, 1), (B, S, H))) * 0.3
    Bm = jax.random.normal(jax.random.fold_in(key, 2), (B, S, 1, N)) * 0.3
    Cm = jax.random.normal(jax.random.fold_in(key, 3), (B, S, 1, N)) * 0.3
    y_chunk, final = ssm_lib._ssd_chunked(xdt, a, Bm, Cm, s)
    # naive recurrence
    state = np.zeros((B, H, N, P))
    ys = []
    xn, an, Bn, Cn = map(np.asarray, (xdt, a, Bm, Cm))
    for t in range(S):
        state = state * np.exp(an[:, t])[:, :, None, None] + np.einsum(
            "bgn,bhp->bhnp", Bn[:, t], xn[:, t])
        ys.append(np.einsum("bgn,bhnp->bhp", Cn[:, t], state))
    y_ref = np.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_chunk), y_ref, rtol=2e-4,
                               atol=2e-4)
    np.testing.assert_allclose(np.asarray(final), state, rtol=2e-4, atol=2e-4)


def test_moe_matches_dense_oracle_loose_capacity():
    """Gather-dispatch MoE == explicit per-token expert mixture when nothing
    is dropped."""
    d, E = 16, 4
    cfg = MoEConfig(n_experts=E, top_k=2, d_ff=32, capacity_factor=8.0)
    key = jax.random.PRNGKey(0)
    p = {
        "router": jax.random.normal(key, (d, E)),
        "w1": jax.random.normal(jax.random.fold_in(key, 1), (E, d, 32)) * 0.1,
        "w2": jax.random.normal(jax.random.fold_in(key, 2), (E, 32, d)) * 0.1,
        "w3": jax.random.normal(jax.random.fold_in(key, 3), (E, d, 32)) * 0.1,
    }
    x = jax.random.normal(jax.random.fold_in(key, 4), (2, 9, d)) * 0.5
    y, aux = moe_lib.moe_apply(p, x, cfg)
    # oracle: every token through its top-2 experts densely
    xf = np.asarray(x.reshape(-1, d), np.float32)
    w, ids, _ = moe_lib.route(jnp.asarray(xf), p["router"], cfg)
    w, ids = np.asarray(w), np.asarray(ids)
    ref = np.zeros_like(xf)
    for t in range(xf.shape[0]):
        for j in range(2):
            e = ids[t, j]
            h = np.asarray(jax.nn.silu(xf[t] @ p["w1"][e])) * (
                xf[t] @ np.asarray(p["w3"][e]))
            ref[t] += w[t, j] * (h @ np.asarray(p["w2"][e]))
    np.testing.assert_allclose(np.asarray(y.reshape(-1, d)), ref, rtol=5e-3,
                               atol=5e-4)
    assert float(aux) > 0


def test_rglru_scan_matches_step():
    from repro.configs.base import RGLRUConfig
    from repro.models import rglru as rg
    cfg = RGLRUConfig(lru_width=8, conv_width=4)
    key = jax.random.PRNGKey(0)
    p = jax.tree_util.tree_map(
        lambda pd: jax.random.normal(jax.random.PRNGKey(hash(str(pd)) %
                                                        (2**31)),
                                     pd.shape) * 0.2,
        rg.rglru_defs(8, cfg, jnp.float32),
        is_leaf=lambda x: hasattr(x, "kind"))
    x = jax.random.normal(jax.random.fold_in(key, 9), (1, 11, 8)) * 0.5
    y_scan = rg.rglru_apply(p, x, cfg)
    cache = rg.rglru_init_cache(1, cfg, jnp.float32)
    ys = []
    for t in range(11):
        y1, cache = rg.rglru_decode(p, x[:, t:t + 1], cache, cfg)
        ys.append(y1)
    y_step = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_scan), np.asarray(y_step),
                               rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("arch", ["yi-6b", "deepseek-7b",
                                  "recurrentgemma-2b"])
def test_int8_kv_cache_decode(arch):
    """Quantized (int8 + per-vector scale) KV cache: decode matches the full
    forward within the quantization tolerance, and the cache is int8."""
    cfg = registry.get_smoke_config(arch)
    m = Model(cfg)
    key = jax.random.PRNGKey(5)
    params = m.init(key)
    B, S = 2, 24
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab)
    full = m.forward(params, Batch(tokens=tokens))
    _, cache, pos = m.prefill(params, Batch(tokens=tokens[:, :S - 1]),
                              max_seq=S + 4, kv_dtype="int8")
    leaves = {jax.tree_util.keystr(p): l for p, l in
              jax.tree_util.tree_leaves_with_path(cache)}
    assert any(l.dtype == jnp.int8 for l in leaves.values())
    logits, _ = m.decode_step(params, cache, tokens[:, S - 1:S],
                              jnp.int32(pos), kv_dtype="int8")
    rel = float(jnp.max(jnp.abs(logits - full[:, -1]))) / float(
        jnp.max(jnp.abs(full[:, -1])))
    assert rel < 0.05, (arch, rel)
