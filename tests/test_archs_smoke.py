"""Per-architecture smoke tests (deliverable f): a REDUCED variant of each
assigned architecture runs one train step and one decode step on CPU with
correct shapes and no NaNs."""

import jax
import jax.numpy as jnp
import pytest

from repro import compat
from repro.configs import registry
from repro.configs.shapes import SHAPES
from repro.core.planner import Planner
from repro.models.transformer import Batch, Model
from repro.optim import optimizers as opt_lib
from repro.train import trainer as tr


@pytest.fixture(scope="module")
def mesh():
    return compat.make_mesh((1, 1), ("data", "model"),
                            axis_types=(compat.AxisType.Auto,) * 2)


def _batch(cfg, key, B, S, with_labels=True):
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab)
    kw = {}
    if cfg.vlm_img_tokens:
        kw["img_embeds"] = jax.random.normal(
            key, (B, cfg.vlm_img_tokens, cfg.vlm_d_vision))
    if cfg.encoder is not None:
        kw["frame_embeds"] = jax.random.normal(
            key, (B, cfg.encoder.n_frames, cfg.encoder.d_input))
    return Batch(tokens=tokens, labels=tokens if with_labels else None, **kw)


@pytest.mark.parametrize("arch", registry.ARCH_IDS)
def test_smoke_train_step(arch, mesh):
    cfg = registry.get_smoke_config(arch)
    assert cfg.d_model <= 512 and cfg.n_layers <= 6
    if cfg.moe is not None:
        assert cfg.moe.n_experts <= 4
    model = Model(cfg)
    opt = opt_lib.adamw(1e-3)
    planner = Planner(mesh=mesh)
    with compat.set_mesh(mesh):
        state = tr.make_train_state(model, opt, jax.random.PRNGKey(0))
        step = jax.jit(tr.make_train_step(model, opt, mesh, planner,
                                          tr.CommConfig()))
        batch = _batch(cfg, jax.random.PRNGKey(1), B=2, S=24)
        new_state, metrics = step(state, batch)
    assert jnp.isfinite(metrics["loss"]), arch
    assert int(new_state.step) == 1
    # params changed and are finite
    leaves = jax.tree_util.tree_leaves(new_state.params)
    assert all(bool(jnp.all(jnp.isfinite(l))) for l in leaves), arch


@pytest.mark.parametrize("arch", registry.ARCH_IDS)
def test_smoke_decode_step(arch, mesh):
    cfg = registry.get_smoke_config(arch)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 2, 12
    batch = _batch(cfg, jax.random.PRNGKey(1), B, S, with_labels=False)
    logits, cache, pos = model.prefill(params, batch, max_seq=S + 8)
    assert logits.shape == (B, cfg.vocab)
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    logits2, cache2 = model.decode_step(params, cache, tok, jnp.int32(pos))
    assert logits2.shape == (B, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits2))), arch


@pytest.mark.parametrize("arch", registry.ARCH_IDS)
def test_full_config_matches_assignment(arch):
    """The FULL configs carry the exact assigned hyperparameters."""
    cfg = registry.get_config(arch)
    expected = {
        "yi-6b": (32, 4096, 64000), "llava-next-mistral-7b": (32, 4096, 32000),
        "minicpm3-4b": (62, 2560, 73448), "arctic-480b": (35, 7168, 32000),
        "chatglm3-6b": (28, 4096, 65024), "mamba2-2.7b": (64, 2560, 50280),
        "recurrentgemma-2b": (26, 2560, 256000),
        "grok-1-314b": (64, 6144, 131072),
        "whisper-small": (12, 768, 51865), "deepseek-7b": (30, 4096, 102400),
    }[arch]
    assert (cfg.n_layers, cfg.d_model, cfg.vocab) == expected
    assert cfg.source
    if arch == "arctic-480b":
        assert cfg.moe.n_experts == 128 and cfg.moe.top_k == 2
        assert cfg.moe.dense_residual_ff > 0
    if arch == "grok-1-314b":
        assert cfg.moe.n_experts == 8 and cfg.moe.top_k == 2
    if arch == "mamba2-2.7b":
        assert cfg.ssm.d_state == 128 and cfg.attn is None
    if arch == "recurrentgemma-2b":
        assert cfg.block_pattern == ("rglru", "rglru", "local")


def test_shapes_match_assignment():
    assert SHAPES["train_4k"].seq_len == 4096
    assert SHAPES["train_4k"].global_batch == 256
    assert SHAPES["prefill_32k"].seq_len == 32768
    assert SHAPES["prefill_32k"].global_batch == 32
    assert SHAPES["decode_32k"].global_batch == 128
    assert SHAPES["long_500k"].seq_len == 524288
    assert SHAPES["long_500k"].global_batch == 1
