"""Multi-rank semantics, exercised in subprocesses with 8 fake host devices
(so the main pytest process keeps the normal 1-device view)."""

import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(code: str, n_dev: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_dev}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_wire_formats_match_psum_across_8_ranks():
    _run(r"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro import compat
from repro.core import collectives as cl
mesh = compat.make_mesh((8,), ("data",), axis_types=(compat.AxisType.Auto,))
x = jax.random.normal(jax.random.PRNGKey(0), (8, 4096)) * 1e-3
def f(wire):
    def inner(u):
        return cl.allreduce(u[0], ("data",), wire=wire)
    return jax.jit(compat.shard_map(inner, mesh=mesh, in_specs=P("data"),
                         out_specs=P(), axis_names={"data"},
                         check_vma=False))(x)
ref = np.asarray(jnp.sum(x, 0))
for wire, tol in (("fp32", 1e-6), ("bf16", 3e-2), ("int8", 2e-2)):
    got = np.asarray(f(wire))
    err = np.max(np.abs(got - ref)) / np.max(np.abs(ref))
    assert err < tol, (wire, err)
print("ok")
""")


def test_mlsl_8rank_training_matches_gspmd():
    _run(r"""
import jax, jax.numpy as jnp, numpy as np
from repro import compat
from repro.configs import registry
from repro.core.planner import Planner
from repro.data import pipeline
from repro.models.transformer import Batch, Model
from repro.optim import optimizers as opt_lib
from repro.train import trainer as tr
mesh = compat.make_mesh((4, 2), ("data", "model"),
                        axis_types=(compat.AxisType.Auto,) * 2)
cfg = registry.get_smoke_config("yi-6b")
model = Model(cfg); opt = opt_lib.adamw(3e-3)
planner = Planner(mesh=mesh)
dcfg = pipeline.DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=8)
results = {}
for mode in ("gspmd", "mlsl"):
    comm = tr.CommConfig(mode=mode)
    with compat.set_mesh(mesh):
        state = tr.make_train_state(model, opt, jax.random.PRNGKey(0))
        step = jax.jit(tr.make_train_step(model, opt, mesh, planner, comm))
        for raw in pipeline.iterate(dcfg, 3):
            batch = Batch(tokens=jnp.asarray(raw["tokens"]),
                          labels=jnp.asarray(raw["labels"]))
            state, m = step(state, batch)
    results[mode] = (float(m["loss"]), state.params)
assert abs(results["gspmd"][0] - results["mlsl"][0]) < 1e-4, results
# identical math, different reduction order: mean-of-shard-means vs global
# mean; Adam's normalizer amplifies the fp noise, so tolerances are loose
jax.tree_util.tree_map(
    lambda a, b: np.testing.assert_allclose(np.asarray(a, np.float32),
                                            np.asarray(b, np.float32),
                                            rtol=1e-2, atol=5e-4),
    results["gspmd"][1], results["mlsl"][1])
print("ok")
""")


def test_ep_moe_matches_gather_moe_8ranks():
    _run(r"""
import dataclasses, jax, jax.numpy as jnp, numpy as np
from repro import compat
from repro.configs.base import MoEConfig
from repro.models import moe as moe_lib
mesh = compat.make_mesh((2, 4), ("data", "model"),
                        axis_types=(compat.AxisType.Auto,) * 2)
d, E = 16, 8
cfg = MoEConfig(n_experts=E, top_k=2, d_ff=32, capacity_factor=8.0)
key = jax.random.PRNGKey(0)
p = {"router": jax.random.normal(key, (d, E)),
     "w1": jax.random.normal(jax.random.fold_in(key, 1), (E, d, 32)) * .1,
     "w2": jax.random.normal(jax.random.fold_in(key, 2), (E, 32, d)) * .1,
     "w3": jax.random.normal(jax.random.fold_in(key, 3), (E, d, 32)) * .1}
x = jax.random.normal(jax.random.fold_in(key, 4), (4, 8, d)) * .5
with compat.set_mesh(mesh):
    y_ref, aux_ref = jax.jit(lambda p, x: moe_lib.moe_apply(p, x, cfg))(p, x)
    y_ep, aux_ep = jax.jit(lambda p, x: moe_lib.moe_apply_ep(
        p, x, cfg, act="silu", mesh=mesh, batch_axes=("data",)))(p, x)
np.testing.assert_allclose(np.asarray(y_ep), np.asarray(y_ref), rtol=2e-3,
                           atol=2e-4)
print("ok")
""")


def test_ep_int8_wgather_grads_flow():
    """Quantized ZeRO weight gathers must pass straight-through gradients
    (a plain grad-of-round would silently zero the expert updates)."""
    _run(r'''
import jax, jax.numpy as jnp, numpy as np
from repro import compat
from repro.configs.base import MoEConfig
from repro.models import moe as moe_lib
mesh = compat.make_mesh((2, 4), ("data", "model"),
                        axis_types=(compat.AxisType.Auto,) * 2)
d, E = 16, 8
cfg = MoEConfig(n_experts=E, top_k=2, d_ff=32, capacity_factor=8.0)
key = jax.random.PRNGKey(0)
p = {"router": jax.random.normal(key, (d, E)),
     "w1": jax.random.normal(jax.random.fold_in(key, 1), (E, d, 32)) * .1,
     "w2": jax.random.normal(jax.random.fold_in(key, 2), (E, 32, d)) * .1,
     "w3": jax.random.normal(jax.random.fold_in(key, 3), (E, d, 32)) * .1}
x = jax.random.normal(jax.random.fold_in(key, 4), (4, 8, d)) * .5
def loss(p, x, wire):
    y, aux = moe_lib.moe_apply_ep(p, x, cfg, act="silu", mesh=mesh,
                                  batch_axes=("data",), fsdp_axes=("data",),
                                  wgather_wire=wire)
    return jnp.mean(y.astype(jnp.float32) ** 2)
with compat.set_mesh(mesh):
    g_ref = jax.jit(jax.grad(loss), static_argnums=2)(p, x, "bf16")
    g_q = jax.jit(jax.grad(loss), static_argnums=2)(p, x, "int8")
for k in ("w1", "w2", "w3"):
    assert float(jnp.max(jnp.abs(g_q[k]))) > 0, k
    err = float(jnp.max(jnp.abs(g_q[k] - g_ref[k])))
    ref = float(jnp.max(jnp.abs(g_ref[k]))) + 1e-9
    assert err / ref < 0.1, (k, err / ref)
print("ok")
''')


@pytest.mark.slow
def test_dryrun_one_combo_subprocess():
    """launch/dryrun end to end on the 512-device production mesh."""
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "mamba2-2.7b",
         "--shape", "long_500k", "--out", "/tmp/dryrun_test"],
        env=env, capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "[ok]" in out.stdout
