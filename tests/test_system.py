"""End-to-end behaviour: the public API pipeline (Session -> train -> save ->
restore -> serve) on a reduced model."""

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat
from repro.checkpoint import ckpt
from repro.configs import registry
from repro.core.api import Session
from repro.data import pipeline
from repro.models.transformer import Batch, Model
from repro.optim import optimizers as opt_lib
from repro.serve.engine import Engine, EngineConfig
from repro.train import trainer as tr


def test_full_pipeline(tmp_path):
    mesh = compat.make_mesh((1, 1), ("data", "model"),
                            axis_types=(compat.AxisType.Auto,) * 2)
    cfg = registry.get_smoke_config("yi-6b")
    model = Model(cfg)
    sess = Session.create(mesh, n_params=model.n_params(),
                          comm=tr.CommConfig(mode="mlsl", wire="bf16"))
    opt = opt_lib.adamw(3e-3)
    dcfg = pipeline.DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=4)
    with compat.set_mesh(mesh):
        state = tr.make_train_state(model, opt, jax.random.PRNGKey(0))
        step = jax.jit(sess.make_train_step(model, opt))
        first = last = None
        for raw in pipeline.iterate(dcfg, 20):
            b = Batch(tokens=jnp.asarray(raw["tokens"]),
                      labels=jnp.asarray(raw["labels"]))
            state, m = step(state, b)
            first = first if first is not None else float(m["loss"])
            last = float(m["loss"])
    assert last < first - 0.2

    d = ckpt.save(str(tmp_path / "ck"), {"params": state.params}, step=20)
    restored = ckpt.restore(d, {"params": state.params})["params"]
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_array_equal(
            np.asarray(a, np.float32), np.asarray(b, np.float32)),
        state.params, restored)

    eng = Engine(model, restored, EngineConfig(max_seq=48))
    out = eng.generate(np.zeros((2, 4), np.int32), 5)
    assert out.shape == (2, 5)
    assert sess.wire_savings() > 1.5     # bf16 wire halves the volume
