import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import pytest  # noqa: E402


@pytest.fixture(scope="session")
def mesh11():
    return jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)


@pytest.fixture(scope="session")
def abstract_pod():
    from jax.sharding import AbstractMesh
    return AbstractMesh((16, 16), ("data", "model"))


def assert_one_device():
    assert jax.device_count() == 1
