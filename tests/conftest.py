import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
# repo root: `benchmarks` / `scripts` namespace packages (perf ledger tests)
sys.path.insert(1, os.path.join(os.path.dirname(__file__), ".."))

# Give the in-process suite an 8-chip view of the CPU so multi-rank
# semantics (hierarchical collectives, factored meshes) are testable
# without hardware. Must happen BEFORE jax is imported anywhere
# (SNIPPETS.md idiom); subprocess tests that need a different count
# override XLA_FLAGS in their own environment.
N_VIRTUAL_DEVICES = 8
if "--xla_force_host_platform_device_count" not in \
        os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={N_VIRTUAL_DEVICES} "
        + os.environ.get("XLA_FLAGS", ""))

import jax  # noqa: E402
import pytest  # noqa: E402

from repro import compat  # noqa: E402


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: long-running end-to-end tests (dry-run compiles)")


@pytest.fixture(scope="session")
def mesh11():
    return compat.make_mesh((1, 1), ("data", "model"),
                            axis_types=(compat.AxisType.Auto,) * 2)


@pytest.fixture(scope="session")
def mesh8():
    """("node"=2, "local"=4) factored data-parallel mesh over the 8 virtual
    devices -- the hierarchical-collectives test mesh."""
    from repro.launch import mesh as mesh_lib
    return mesh_lib.make_hier_mesh(node=2, local=4)


@pytest.fixture(scope="session")
def abstract_pod():
    return compat.abstract_mesh((16, 16), ("data", "model"))

