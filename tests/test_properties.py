"""Hypothesis property tests on system invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis "
    "(pip install -r requirements-dev.txt)")
import hypothesis.strategies as st  # noqa: E402
from hypothesis import given, settings  # noqa: E402

from repro.core import c2c, hw, scheduler, simulator as sim
from repro.kernels import ops
from repro.models import moe as moe_lib
from repro.configs.base import MoEConfig

hypothesis.settings.register_profile(
    "ci", settings(max_examples=20, deadline=None))
hypothesis.settings.load_profile("ci")


@given(st.integers(1, 5000), st.floats(1e-6, 1e3))
def test_quantization_error_bound(n, scale):
    """|x - dq(q(x))| <= blockwise amax / 127 (one step of rounding)."""
    x = np.random.RandomState(n).randn(n).astype(np.float32) * scale
    q, s, meta = ops.quantize(jnp.asarray(x), backend="jnp")
    xr = np.asarray(ops.dequantize(q, s, meta, backend="jnp"))
    assert np.max(np.abs(x - xr)) <= np.max(np.abs(x)) / 127.0 + 1e-6 * scale


@given(st.lists(st.integers(1, 400), min_size=1, max_size=8),
       st.integers(64, 4096))
def test_bucket_fuse_unfuse_partition(sizes, bucket_bytes):
    tree = {f"l{i}": jnp.arange(float(s)) for i, s in enumerate(sizes)}
    plan = scheduler.plan_buckets(tree, bucket_bytes=float(bucket_bytes))
    leaves = jax.tree_util.tree_leaves(tree)
    seen = set()
    for b in plan.buckets:
        flat = scheduler.fuse_bucket(leaves, b)
        assert flat.size == b.n_elems
        back = scheduler.unfuse_bucket(flat, b)
        for lid, leaf in back.items():
            assert lid not in seen
            seen.add(lid)
            np.testing.assert_array_equal(np.asarray(leaf),
                                          np.asarray(leaves[lid]))
    assert seen == set(range(len(leaves)))


@given(st.integers(2, 512), st.integers(1, 9))
def test_hybrid_ratio_bounded_by_extremes(p_exp, g_exp):
    p = 2 ** int(np.log2(p_exp))
    p = max(p, 2)
    g = 2 ** g_exp
    if g > p:
        g = p
    l = c2c.fc_layer("fc", 1024, 1024)
    r = c2c.hybrid_ratio(l, 256, p, g)
    assert r >= 0


@given(st.integers(2, 128), st.floats(0.2, 1.0))
def test_simulator_policy_dominance(p, eta):
    layers = [sim.SimLayer(f"l{i}", 1e-3, 2e-3, 4e6 * (i + 1))
              for i in range(6)]
    prio = sim.simulate_iteration(layers, p, hw.ETH_10G,
                                  sim.Policy.PRIORITY_OVERLAP,
                                  overlap_eff=eta)
    fifo = sim.simulate_iteration(layers, p, hw.ETH_10G,
                                  sim.Policy.FIFO_OVERLAP, overlap_eff=eta)
    assert prio.exposed_comm <= fifo.exposed_comm + 1e-9
    assert prio.exposed_comm >= -1e-9


@given(st.integers(4, 200), st.integers(2, 8), st.integers(1, 2))
def test_moe_dispatch_indices_valid(t, e, k):
    cfg = MoEConfig(n_experts=e, top_k=k, d_ff=8)
    ids = jnp.asarray(np.random.RandomState(t).randint(0, e, size=(t, k)))
    cap = moe_lib.capacity(t, cfg)
    slot_token, slot_valid, slot_wsrc = moe_lib._dispatch_indices(ids, cfg,
                                                                  cap)
    st_, sv, sw = (np.asarray(slot_token), np.asarray(slot_valid),
                   np.asarray(slot_wsrc))
    assert st_.shape == (e * cap,)
    assert (st_[sv] >= 0).all() and (st_[sv] < t).all()
    # every valid slot's expert (slot // cap) matches the routed expert
    slots = np.arange(e * cap)
    experts = slots // cap
    flat_ids = np.asarray(ids).reshape(-1)
    assert (flat_ids[sw[sv]] == experts[sv]).all()
    # no token-choice duplicated into two slots
    assert len(np.unique(sw[sv])) == sv.sum()


@given(st.integers(0, 10000))
def test_data_pipeline_deterministic(step):
    from repro.data import pipeline
    cfg = pipeline.DataConfig(vocab=97, seq_len=16, global_batch=4)
    a = pipeline.batch_at(cfg, step)["tokens"]
    b = pipeline.batch_at(cfg, step)["tokens"]
    np.testing.assert_array_equal(a, b)
    assert a.min() >= 0 and a.max() < 97
