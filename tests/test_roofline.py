"""Unit tests for the roofline HLO parser (collective accounting)."""

import pytest

from repro.launch import roofline as rf

HLO = """
ENTRY %main (p0: bf16[128,4096]) -> bf16[128,4096] {
  %ag = bf16[2048,4096]{1,0} all-gather(%x), replica_groups=[16,16]<=[256], dimensions={0}
  %ar = f32[128,4096]{1,0} all-reduce(%y), replica_groups=[16,16]<=[256], to_apply=%add
  %w = bf16[8] while(%init), body=%body.1, condition=%cond.1
}
%body.1 (p: bf16[8]) -> bf16[8] {
  %ar2 = bf16[64,512]{1,0} all-reduce(%z), replica_groups=[16,16]<=[256], to_apply=%add
  %w2 = bf16[8] while(%q), body=%body.2, condition=%cond.2
}
%body.2 (p: bf16[8]) -> bf16[8] {
  %a2a = bf16[16,1024]{1,0} all-to-all(%u), replica_groups=[16,16]<=[256]
}
"""


def test_group_size_parse():
    assert rf._group_size("replica_groups=[16,16]<=[256]", 999) == 16
    assert rf._group_size("replica_groups={{0,1,2,3}}", 999) == 4
    assert rf._group_size("no groups here", 7) == 7


def test_wire_formulas():
    assert rf._wire_bytes("all-reduce", 100.0, 4) == pytest.approx(150.0)
    assert rf._wire_bytes("all-gather", 100.0, 4) == pytest.approx(75.0)
    assert rf._wire_bytes("reduce-scatter", 25.0, 4) == pytest.approx(75.0)
    assert rf._wire_bytes("all-reduce", 100.0, 1) == 0.0


def test_loop_nesting_multipliers():
    out = rf.collective_wire_bytes(HLO, n_chips=256, loop_mult=10.0)
    # entry ops x1; depth-1 body x10; depth-2 body x10 (no outer loop)
    ag = 2048 * 4096 * 2 * 15 / 16
    ar = 128 * 4096 * 4 * 2 * 15 / 16
    ar2 = 64 * 512 * 2 * 2 * 15 / 16 * 10
    a2a = 16 * 1024 * 2 * 15 / 16 * 10
    assert out["all-gather"] == pytest.approx(ag, rel=1e-6)
    assert out["all-reduce"] == pytest.approx(ar + ar2, rel=1e-6)
    assert out["all-to-all"] == pytest.approx(a2a, rel=1e-6)


def test_nested_accumulation_multipliers():
    out = rf.collective_wire_bytes(HLO, n_chips=256, loop_mult=10.0,
                                   outer_mult=4.0)
    # depth-1 body x4 (accum); depth-2 body x40 (accum x layers)
    ar2 = 64 * 512 * 2 * 2 * 15 / 16 * 4
    a2a = 16 * 1024 * 2 * 15 / 16 * 40
    assert out["all-reduce"] == pytest.approx(
        128 * 4096 * 4 * 2 * 15 / 16 + ar2, rel=1e-6)
    assert out["all-to-all"] == pytest.approx(a2a, rel=1e-6)


def test_analyze_terms_and_dominant():
    r = rf.analyze(arch="a", shape="s", mesh_name="m", chips=256,
                   cost_full={"flops": 1e12, "bytes accessed": 1e12},
                   cost_block={"flops": 1e11, "bytes accessed": 1e11},
                   repeats=10, hlo_text=HLO, model_flops=2.56e14, accum=1)
    assert r.hlo_flops == pytest.approx(1e12 + 9 * 1e11)
    assert r.dominant in ("compute", "memory", "collective")
    assert r.useful_ratio == pytest.approx(2.56e14 / (r.hlo_flops * 256))


def test_analyze_accum_scaling():
    r1 = rf.analyze(arch="a", shape="s", mesh_name="m", chips=256,
                    cost_full={"flops": 1e12, "bytes accessed": 0.0},
                    cost_block={"flops": 1e11, "bytes accessed": 0.0},
                    repeats=10, hlo_text="", model_flops=1.0, accum=4)
    # accum x repeats - 1 block costs on top
    assert r1.hlo_flops == pytest.approx(1e12 + 39 * 1e11)
