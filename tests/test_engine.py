"""CommEngine: the unified bucket-reduction data path + overlap mode.

The tentpole claims verified here:

  * the EnginePlan compiles CommConfig + gradient structure + mesh into the
    same routing/fusion decisions the trainer previously inlined;
  * `engine.reduce` is a correct mean-allreduce over the data axes, per-leaf
    for non-fusable (model-sharded) buckets;
  * the overlap schedule is BIT-IDENTICAL to the blocking schedule at fp32
    (same operation sequence, different barrier structure) — the engine
    equivalence acceptance criterion;
  * the trainer is fully decoupled from hier/route_buckets (all bucket
    reduction flows through the engine);
  * the simulator's overlap-aware bucket-schedule estimate behaves.
"""

import inspect

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.configs import registry
from repro.core import engine as eng
from repro.core import hier, hw, planner, scheduler, simulator as sim
from repro.core.api import Session
from repro.core.planner import Planner
from repro.data import pipeline
from repro.models.transformer import Batch, Model
from repro.optim import optimizers as opt_lib
from repro.train import trainer as tr

DSPEC = P((hier.NODE_AXIS, hier.LOCAL_AXIS))
DATA_AXES = (hier.NODE_AXIS, hier.LOCAL_AXIS)


def _tree():
    k = jax.random.PRNGKey(7)
    return {"embed": jax.random.normal(k, (32, 8)),
            "w": jax.random.normal(jax.random.fold_in(k, 1), (64, 16)),
            "head": jax.random.normal(jax.random.fold_in(k, 2), (8, 32))}


# --------------------------------------------------------------------------
# EnginePlan construction
# --------------------------------------------------------------------------

def test_build_plan_flat_defaults(mesh8):
    plan = eng.build_plan(_tree(), eng.CommConfig(mode="mlsl"), mesh8,
                          DATA_AXES)
    assert plan.n_buckets >= 1
    assert plan.dp == 8 and plan.n_node == 1 and plan.n_local == 8
    assert all(a == planner.ALGO_FLAT for a in plan.algos)
    assert all(plan.fusable)
    assert plan.hier_spec is None


def test_build_plan_hier_topo_routing(mesh8):
    comm = eng.CommConfig(mode="mlsl", hier=True, topo="xeon-shm-10gbe")
    plan = eng.build_plan(_tree(), comm, mesh8, DATA_AXES)
    assert plan.n_node == 2 and plan.n_local == 4
    assert plan.hier_spec is not None
    assert len(plan.algos) == plan.n_buckets
    assert all(a in (planner.ALGO_FLAT, planner.ALGO_HIER)
               for a in plan.algos)


def test_build_plan_requires_factored_mesh_for_hier(mesh11):
    with pytest.raises(AssertionError, match="node"):
        eng.build_plan(_tree(), eng.CommConfig(mode="mlsl", hier=True),
                       mesh11, ("data",))


def test_build_plan_unknown_topo(mesh8):
    with pytest.raises(ValueError, match="unknown topology"):
        eng.build_plan(_tree(),
                       eng.CommConfig(mode="mlsl", hier=True, topo="nope"),
                       mesh8, DATA_AXES)


def test_build_plan_zero_fusable_and_empty(mesh8):
    """All-model-sharded tree: no bucket may fuse; empty tree: no buckets."""
    plan = eng.build_plan(_tree(), eng.CommConfig(mode="mlsl"), mesh8,
                          DATA_AXES, leaf_replicated=lambda path: False)
    assert plan.n_buckets >= 1 and not any(plan.fusable)
    empty = eng.build_plan({}, eng.CommConfig(mode="mlsl"), mesh8, DATA_AXES)
    assert empty.n_buckets == 0 and empty.bucket_bytes_list() == ()


# --------------------------------------------------------------------------
# the data path
# --------------------------------------------------------------------------

def _reduce8(mesh8, comm, tree, **plan_kw):
    """engine.reduce inside a manual region over both mesh8 axes; inputs are
    split over the ranks, output is the (replicated) mean."""
    engine = eng.CommEngine.create(
        jax.eval_shape(lambda: jax.tree_util.tree_map(
            lambda x: x[0], tree)), comm, mesh8, DATA_AXES, **plan_kw)

    def f(t):
        local = jax.tree_util.tree_map(lambda x: x[0], t)
        out, _ = engine.reduce(local, None)
        return out

    return jax.jit(compat.shard_map(
        f, mesh=mesh8,
        in_specs=(jax.tree_util.tree_map(lambda _: DSPEC, tree),),
        out_specs=jax.tree_util.tree_map(lambda _: P(), tree)))(tree)


@pytest.fixture(scope="module")
def stacked_tree():
    k = jax.random.PRNGKey(3)
    return {"a": jax.random.normal(k, (8, 1000)),
            "b": jax.random.normal(jax.random.fold_in(k, 1), (8, 33, 7))}


def test_engine_reduce_is_mean_allreduce(mesh8, stacked_tree):
    got = _reduce8(mesh8, eng.CommConfig(mode="mlsl"), stacked_tree)
    jax.tree_util.tree_map(
        lambda g, x: np.testing.assert_allclose(
            np.asarray(g), np.mean(np.asarray(x), axis=0),
            rtol=1e-6, atol=1e-7),
        got, stacked_tree)


def test_engine_reduce_per_leaf_when_not_fusable(mesh8, stacked_tree):
    got = _reduce8(mesh8, eng.CommConfig(mode="mlsl"), stacked_tree,
                   leaf_replicated=lambda path: False)
    jax.tree_util.tree_map(
        lambda g, x: np.testing.assert_allclose(
            np.asarray(g), np.mean(np.asarray(x), axis=0),
            rtol=1e-6, atol=1e-7),
        got, stacked_tree)


def test_engine_skip_reduce_is_identity():
    m = compat.make_mesh((1, 1), ("node", "local"))
    t = _tree()
    engine = eng.CommEngine.create(t, eng.CommConfig(mode="mlsl",
                                                     skip_reduce=True),
                                   m, DATA_AXES)
    out, res = engine.reduce(t, None)
    assert res is None
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a),
                                                   np.asarray(b)), out, t)


def test_engine_gate_token(mesh8):
    """The blocking gate depends on every bucket (scalar), and degrades to
    a zero scalar on an empty plan."""
    t = _tree()
    engine = eng.CommEngine.create(t, eng.CommConfig(mode="mlsl"), mesh8,
                                   DATA_AXES)
    tok = engine.gate_token(t)
    assert tok.shape == () and tok.dtype == jnp.float32
    empty = eng.CommEngine.create({}, eng.CommConfig(mode="mlsl"), mesh8,
                                  DATA_AXES)
    assert float(empty.gate_token({})) == 0.0


def test_engine_ef_residual_state(mesh8):
    comm = eng.CommConfig(mode="mlsl", wire="int8", error_feedback=True)
    engine = eng.CommEngine.create(_tree(), comm, mesh8, DATA_AXES)
    assert engine.plan.use_ef
    res = engine.init_residuals()
    assert len(res) == engine.plan.n_buckets
    specs = engine.residual_specs(P(DATA_AXES))
    assert len(specs) == engine.plan.n_buckets
    # flat-routed bucket residuals: dp * per-rank fabric shard
    from repro.core import collectives as cl
    for bi, (r, b) in enumerate(zip(res, engine.plan.buckets.buckets)):
        assert engine.ef_applied(bi)
        assert r.shape == (cl.ef_residual_shape(b.n_elems, 8)[0] * 8,)


def test_engine_ef_residuals_only_where_applied(mesh8):
    """Non-fusable buckets ride the bf16 wire (no EF) and must not be
    allocated fp32 residual buffers — only zero-length placeholders that
    keep the residual-tuple arity and specs aligned."""
    comm = eng.CommConfig(mode="mlsl", wire="int8", error_feedback=True)
    engine = eng.CommEngine.create(_tree(), comm, mesh8, DATA_AXES,
                                   leaf_replicated=lambda path: False)
    assert engine.plan.use_ef and not any(engine.plan.fusable)
    res = engine.init_residuals()
    assert len(res) == engine.plan.n_buckets
    assert all(r.shape == (0,) for r in res)
    res_spec = engine.residual_specs(P(DATA_AXES))
    assert len(res_spec) == engine.plan.n_buckets
    # the data path carries the placeholders through unchanged
    tree = _tree()
    tspec = jax.tree_util.tree_map(lambda _: P(), tree)
    out, new_res = jax.jit(compat.shard_map(
        lambda t, r: engine.reduce(t, r), mesh=mesh8,
        in_specs=(tspec, res_spec), out_specs=(tspec, res_spec),
        axis_names=set(DATA_AXES), check_vma=False))(tree, res)
    assert all(r.shape == (0,) for r in new_res)
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                                rtol=1e-2, atol=1e-2),
        out, tree)


# --------------------------------------------------------------------------
# trainer integration: decoupling + the overlap schedule
# --------------------------------------------------------------------------

def test_trainer_decoupled_from_comm_internals():
    """All bucket reduction flows through CommEngine: the trainer must not
    touch hier / route_buckets / collectives directly."""
    src = inspect.getsource(tr)
    assert "hier" not in src
    assert "route_buckets" not in src
    assert "from repro.core import collectives" not in src


def test_session_builds_engine(mesh8):
    sess = Session.create(mesh8,
                          comm=tr.CommConfig(mode="mlsl", hier=True,
                                             topo="xeon-shm-10gbe"))
    model = Model(registry.get_smoke_config("yi-6b"))
    engine = sess.comm_engine(model)
    assert engine.plan.n_buckets >= 1
    assert engine.plan.n_node == 2 and engine.plan.n_local == 4


def _train(mesh8, comm, steps=2, seed=0):
    cfg = registry.get_smoke_config("yi-6b")
    model = Model(cfg)
    opt = opt_lib.adamw(3e-3)
    pln = Planner(mesh=mesh8)
    dcfg = pipeline.DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=16,
                               seed=seed)
    with compat.set_mesh(mesh8):
        state = tr.make_train_state(model, opt, jax.random.PRNGKey(seed))
        step = jax.jit(tr.make_train_step(model, opt, mesh8, pln, comm))
        losses = []
        for raw in pipeline.iterate(dcfg, steps):
            batch = Batch(tokens=jnp.asarray(raw["tokens"]),
                          labels=jnp.asarray(raw["labels"]))
            state, m = step(state, batch)
            losses.append(float(m["loss"]))
    return losses, state


def test_overlap_bit_identical_to_blocking_fp32(mesh8):
    """The engine equivalence criterion: overlap=True (pipelined microbatch
    reduction) computes the SAME fp32 bits as overlap=False (blocking) —
    only the barrier structure differs."""
    l_off, s_off = _train(mesh8, tr.CommConfig(mode="mlsl", wire="fp32",
                                               accum_steps=2, overlap=False))
    l_on, s_on = _train(mesh8, tr.CommConfig(mode="mlsl", wire="fp32",
                                             accum_steps=2, overlap=True))
    assert l_off == l_on, (l_off, l_on)
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a),
                                                   np.asarray(b)),
        s_off.params, s_on.params)
    # and the losses came from real training steps
    assert l_on[-1] < l_on[0], l_on


def test_overlap_with_hier_routing_trains(mesh8):
    """Pipelined microbatch reduction composes with per-bucket flat-vs-hier
    routing (the full engine path)."""
    comm = tr.CommConfig(mode="mlsl", hier=True, topo="xeon-shm-10gbe",
                         accum_steps=2, overlap=True)
    losses, _ = _train(mesh8, comm, steps=3)
    assert losses[-1] < losses[0], losses


def test_overlap_requires_mlsl(mesh8):
    with pytest.raises(ValueError, match="mlsl"):
        tr.make_train_step(Model(registry.get_smoke_config("yi-6b")),
                           opt_lib.adamw(1e-3), mesh8, Planner(mesh=mesh8),
                           tr.CommConfig(mode="gspmd", overlap=True))


# --------------------------------------------------------------------------
# overlap-aware schedule estimate (simulator + planner)
# --------------------------------------------------------------------------

def test_simulate_bucket_schedule_blocking_exposes_everything():
    st = sim.simulate_bucket_schedule((1e-3, 2e-3), 4, 10e-3, overlap=False)
    np.testing.assert_allclose(st.exposed_comm, 4 * 3e-3)
    np.testing.assert_allclose(st.compute_time, 40e-3)
    np.testing.assert_allclose(st.comm_busy, 4 * 3e-3)


def test_simulate_bucket_schedule_overlap_hides_all_but_drain():
    # comm fits entirely under the next microbatch's compute: only the last
    # microbatch's chain is exposed
    st = sim.simulate_bucket_schedule((1e-3, 2e-3), 4, 10e-3, overlap=True)
    np.testing.assert_allclose(st.exposed_comm, 3e-3)
    off = sim.simulate_bucket_schedule((1e-3, 2e-3), 4, 10e-3, overlap=False)
    assert st.exposed_comm < off.exposed_comm
    np.testing.assert_allclose(off.exposed_comm / st.exposed_comm, 4.0)


def test_simulate_bucket_schedule_single_microbatch_degenerates():
    on = sim.simulate_bucket_schedule((5e-3,), 1, 10e-3, overlap=True)
    off = sim.simulate_bucket_schedule((5e-3,), 1, 10e-3, overlap=False)
    # reduce-at-end either way, fully exposed
    assert (on.total_time, on.exposed_comm) == (off.total_time,
                                                off.exposed_comm)
    np.testing.assert_allclose(on.exposed_comm, 5e-3)


def test_simulate_bucket_schedule_comm_bound_queues():
    # comm >> compute: the link is the bottleneck; exposed = total queue
    # drain past the compute, and overlap still helps vs blocking
    on = sim.simulate_bucket_schedule((50e-3,), 3, 1e-3, overlap=True)
    off = sim.simulate_bucket_schedule((50e-3,), 3, 1e-3, overlap=False)
    np.testing.assert_allclose(on.total_time, 1e-3 + 3 * 50e-3)
    assert on.exposed_comm < off.exposed_comm


def test_estimate_overlap_on_engine_plan(mesh8):
    plan = eng.build_plan(_tree(), eng.CommConfig(mode="mlsl"), mesh8,
                          DATA_AXES)
    off, on = planner.estimate_overlap(plan.buckets.buckets, plan.algos,
                                       2, hw.CLOUD_10G, 4, 5e-3)
    assert off.exposed_comm >= on.exposed_comm >= 0.0
    assert off.comm_busy == on.comm_busy > 0.0
    times = planner.bucket_allreduce_times(plan.buckets.buckets, plan.algos,
                                           2, hw.CLOUD_10G)
    assert len(times) == plan.n_buckets and all(t > 0 for t in times)
