import numpy as np
import pytest

from repro.data import pipeline


def test_host_slice_partitions():
    slices = [pipeline.host_slice(64, 4, h) for h in range(4)]
    ids = np.concatenate([np.arange(64)[s] for s in slices])
    np.testing.assert_array_equal(np.sort(ids), np.arange(64))


def test_learnable_structure():
    """Adjacent tokens must be predictable (else loss-decrease tests lie)."""
    cfg = pipeline.DataConfig(vocab=101, seq_len=64, global_batch=8,
                              noise=0.0)
    b = pipeline.batch_at(cfg, 0)["tokens"]
    diffs = (b[:, 1:] - b[:, :-1]) % 101
    # step size constant per row in the noiseless stream
    assert (diffs == diffs[:, :1]).mean() > 0.95


def test_memmap_mode(tmp_path):
    path = tmp_path / "toks.bin"
    np.arange(100000, dtype=np.uint16).tofile(path)
    cfg = pipeline.DataConfig(vocab=500, seq_len=32, global_batch=4,
                              kind="memmap", path=str(path))
    b = pipeline.batch_at(cfg, 3)
    assert b["tokens"].shape == (4, 32)
    assert b["tokens"].max() < 500


def test_vlm_seq_adjustment():
    from repro.configs import registry
    from repro.configs.shapes import SHAPES
    cfg = registry.get_config("llava-next-mistral-7b")
    d = pipeline.data_config_for(cfg, SHAPES["train_4k"])
    assert d.seq_len == 4096 - cfg.vlm_img_tokens
