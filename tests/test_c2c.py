"""The paper's C2C-ratio claims (Section 'Design choices and insights')."""

import math

from repro.core import c2c, hw


def test_data_parallel_ratio_proportional_to_batch():
    l = c2c.conv_layer("c", 128, 256, 3, 28, 28)
    r1 = c2c.data_parallel_ratio(l, 32, 64)
    r2 = c2c.data_parallel_ratio(l, 64, 64)
    assert abs(r2 / r1 - 2.0) < 1e-9


def test_data_parallel_ratio_independent_of_kernel_feat_stride():
    """Paper: 'it does not depend on the kernel size or number of
    input/output feature maps or stride'."""
    base = c2c.conv_layer("c", 256, 256, 3, 14, 14)
    r0 = c2c.data_parallel_ratio(base, 64, 64)
    for v in (c2c.conv_layer("c", 256, 256, 5, 14, 14),
              c2c.conv_layer("c", 512, 1024, 3, 14, 14),
              c2c.conv_layer("c", 64, 64, 7, 14, 14, stride=2)):
        assert abs(c2c.data_parallel_ratio(v, 64, 64) - r0) < 1e-9 * r0


def test_hybrid_extremes_match_pure_strategies():
    """Group size 1 == data parallelism; group size p == model parallelism."""
    l = c2c.fc_layer("fc", 4096, 4096)
    p = 16
    assert math.isclose(c2c.hybrid_ratio(l, 256, p, 1),
                        c2c.data_parallel_ratio(l, 256, p), rel_tol=1e-9)
    assert math.isclose(c2c.hybrid_ratio(l, 256, p, p),
                        c2c.model_parallel_ratio(l, 256, p), rel_tol=1e-9)


def test_strategy_chooser_conv_vs_fc():
    """Conv layers (small weights, big activations) -> data parallel;
    giant FC layers (big weights, small activations) -> model/hybrid."""
    conv = c2c.conv_layer("c", 64, 64, 3, 56, 56)
    fc = c2c.fc_layer("fc", 25088, 4096)
    c_choice = c2c.choose_strategy(conv, batch=64, p=16)
    f_choice = c2c.choose_strategy(fc, batch=64, p=16)
    assert c_choice.strategy == c2c.Strategy.DATA
    assert f_choice.group_size > 1


def test_exposed_comm_upper_bound_positive():
    layers = [c2c.conv_layer("c", 64, 64, 3, 56, 56)] * 4
    t = c2c.exposed_comm_upper_bound(layers, 32, 16, hw.ETH_10G)
    assert t > 0
