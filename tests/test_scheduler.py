"""Gradient bucketing + priority chaining."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hw, planner, scheduler


def _tree():
    k = jax.random.PRNGKey(0)
    return {
        "embed": jax.random.normal(k, (64, 8)),
        "layers": [{"w": jax.random.normal(jax.random.fold_in(k, i), (32, 16)),
                    "b": jnp.ones((16,))} for i in range(4)],
        "head": jax.random.normal(k, (8, 64)),
    }


def test_plan_covers_every_leaf_once():
    t = _tree()
    plan = scheduler.plan_buckets(t, scheduler.default_layer_index,
                                  bucket_bytes=1 << 12)
    seen = []
    for b in plan.buckets:
        seen.extend(b.leaf_ids)
    assert sorted(seen) == list(range(len(jax.tree_util.tree_leaves(t))))


def test_fuse_unfuse_roundtrip():
    t = _tree()
    leaves = jax.tree_util.tree_leaves(t)
    plan = scheduler.plan_buckets(t, bucket_bytes=1 << 10)
    for b in plan.buckets:
        flat = scheduler.fuse_bucket(leaves, b)
        back = scheduler.unfuse_bucket(flat, b)
        for lid, leaf in back.items():
            np.testing.assert_array_equal(np.asarray(leaf),
                                          np.asarray(leaves[lid]))


def test_priority_order_embed_first_head_last():
    t = _tree()
    plan = scheduler.plan_buckets(t, scheduler.default_layer_index,
                                  bucket_bytes=1.0)  # one leaf per bucket
    leaves_with_paths = jax.tree_util.tree_leaves_with_path(t)
    first = plan.buckets[0].leaf_ids[0]
    last = plan.buckets[-1].leaf_ids[0]
    assert "embed" in str(leaves_with_paths[first][0])
    assert "head" in str(leaves_with_paths[last][0])


def test_reduce_with_priority_preserves_values():
    t = _tree()
    plan = scheduler.plan_buckets(t, scheduler.default_layer_index,
                                  bucket_bytes=1 << 11)

    def reduce_fn(flat, bucket):
        return flat * 2.0

    out = jax.jit(lambda tt: scheduler.reduce_with_priority(
        tt, reduce_fn, plan, prioritize=True))(t)
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_allclose(np.asarray(a) * 2.0,
                                                np.asarray(b), rtol=1e-6),
        t, out)


def test_route_buckets_single_leaf_buckets():
    """bucket_bytes=1.0 degenerates to one leaf per bucket; every bucket
    still gets a route and tiny leaves stay on the flat ring."""
    t = _tree()
    plan = scheduler.plan_buckets(t, bucket_bytes=1.0)
    n_leaves = len(jax.tree_util.tree_leaves(t))
    assert len(plan.buckets) == n_leaves
    assert all(len(b.leaf_ids) == 1 for b in plan.buckets)
    routes = scheduler.route_buckets(plan, hw.CLOUD_10G, nodes=16)
    assert len(routes) == n_leaves
    assert all(r in (planner.ALGO_FLAT, planner.ALGO_HIER) for r in routes)
    # a degenerate hierarchy routes every single-leaf bucket flat
    assert scheduler.route_buckets(plan, hw.CLOUD_10G, nodes=1) \
        == tuple(planner.ALGO_FLAT for _ in plan.buckets)


def test_plan_buckets_group_key_never_fuses_across_groups():
    """A sharding boundary must split buckets even under a huge byte cap
    (the all-model-sharded case: every leaf its own group, zero fusion)."""
    t = {"layers": [{"w": jnp.ones((64, 64)), "b": jnp.ones((64,))}
                    for _ in range(3)]}
    leaves_with_paths = jax.tree_util.tree_leaves_with_path(t)

    def per_leaf_group(path):
        return jax.tree_util.keystr(path)       # all distinct: no fusion

    plan = scheduler.plan_buckets(t, group_key=per_leaf_group,
                                  bucket_bytes=1e12)
    assert len(plan.buckets) == len(leaves_with_paths)
    # and a two-group key fuses within but not across groups
    def parity_group(path):
        return jax.tree_util.keystr(path).endswith("'w']")

    plan2 = scheduler.plan_buckets(t, group_key=parity_group,
                                   bucket_bytes=1e12)
    for b in plan2.buckets:
        keys = {parity_group(leaves_with_paths[i][0]) for i in b.leaf_ids}
        assert len(keys) == 1, b


def test_plan_buckets_empty_tree():
    """An empty gradient tree plans to zero buckets and reduces to itself."""
    for empty in ({}, {"a": {}, "b": []}):
        plan = scheduler.plan_buckets(empty, scheduler.default_layer_index,
                                      bucket_bytes=1 << 20)
        assert plan.buckets == ()
        assert scheduler.route_buckets(plan, hw.CLOUD_10G, nodes=4) == ()
        out = scheduler.reduce_with_priority(empty, lambda x, b: x, plan)
        assert jax.tree_util.tree_leaves(out) == []


def test_priority_chain_in_hlo():
    """With prioritize=True the compiled HLO must contain the barrier chain."""
    t = _tree()
    plan = scheduler.plan_buckets(t, bucket_bytes=1 << 11)
    assert len(plan.buckets) >= 2

    def f(tt):
        return scheduler.reduce_with_priority(tt, lambda x, b: x + 1.0, plan,
                                              prioritize=True)

    txt = jax.jit(f).lower(t).as_text()
    assert "opt-barrier" in txt or "optimization_barrier" in txt
