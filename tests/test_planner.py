"""DL-Layer-API planner: kind -> PartitionSpec rules on the production mesh."""

import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import planner as pl


def _planner(abstract_pod, fsdp=False):
    return pl.Planner(mesh=abstract_pod, fsdp=fsdp)


def test_proj_specs(abstract_pod):
    p = _planner(abstract_pod)
    assert p.spec_for(pl.ParamDef((4096, 11008), pl.K_PROJ_IN)) \
        == P(None, "model")
    assert p.spec_for(pl.ParamDef((11008, 4096), pl.K_PROJ_OUT)) \
        == P("model", None)
    assert p.spec_for(pl.ParamDef((4096,), pl.K_NORM)) == P(None)


def test_indivisible_dims_fall_back(abstract_pod):
    p = _planner(abstract_pod)
    # vocab 73448 is not divisible by 16 -> embed shards d_model instead
    assert p.spec_for(pl.ParamDef((73448, 2560), pl.K_EMBED)) \
        == P(None, "model")
    # nothing divisible -> fully replicated
    assert p.spec_for(pl.ParamDef((51865, 7), pl.K_HEAD)) == P(None, None)


def test_expert_specs(abstract_pod):
    p = _planner(abstract_pod)
    # 128 experts over 16-way model axis
    assert p.spec_for(pl.ParamDef((128, 7168, 4864), pl.K_EXPERT_IN)) \
        == P("model", None, None)
    # 8 experts don't divide 16 -> tensor-parallel over d_ff
    assert p.spec_for(pl.ParamDef((8, 6144, 32768), pl.K_EXPERT_IN)) \
        == P(None, None, "model")


def test_fsdp_adds_batch_axis(abstract_pod):
    p = _planner(abstract_pod, fsdp=True)
    spec = p.spec_for(pl.ParamDef((4096, 11008), pl.K_PROJ_IN))
    assert spec == P("data", "model")


def test_stacked_leading_dim_replicated(abstract_pod):
    p = _planner(abstract_pod)
    spec = p.spec_for(pl.ParamDef((32, 4096, 11008), pl.K_PROJ_IN),
                      stacked=True)
    assert spec == P(None, None, "model")


def test_fsdp_decision():
    assert not pl.decide_fsdp(6e9, 16, train=True)          # yi-6b fits
    assert pl.decide_fsdp(480e9, 16, train=True)            # arctic doesn't
    # even serving a 480B model needs parameter sharding beyond the group
    assert pl.decide_fsdp(480e9, 16, train=False)
    assert not pl.decide_fsdp(7e9, 16, train=False)


def test_batch_and_cache_specs(abstract_pod):
    p = _planner(abstract_pod)
    assert p.tokens_spec(256) == P("data", None)
    assert p.tokens_spec(1) == P(None, None)                # batch 1: replicate
    # GQA kv=4 doesn't divide 16 -> shard the sequence dim instead
    assert p.kv_cache_spec(128, 32768, 4) == P("data", "model", None, None)
    assert p.kv_cache_spec(128, 32768, 16) == P("data", None, "model", None)


def test_plan_report_runs(abstract_pod):
    from repro.configs import cnn_tables
    rep = pl.plan_report(cnn_tables.resnet50_layers(), batch=2048, p=256)
    assert len(rep) > 50
    assert all(r.choice.group_size >= 1 for r in rep)
