"""Perf-ledger schema round-trip and regression-gate semantics
(benchmarks/common.py + scripts/perf_table.py)."""

import json
import os

import pytest

from benchmarks import common
from scripts import perf_table


def _sample_ledger(module="bench_demo", exposed=10.0):
    led = common.Ledger(module)
    led.record("demo/exposed_comm_s", exposed, unit="s")
    led.record("demo/scaling_eff", 0.93)
    led.record("demo/step/us_per_call", 120.0, unit="us", stable=False)
    led.record("demo/algo", "hier")
    led.record("demo/wire_bytes", 4096.0, better=None)
    return led


# --------------------------------------------------------------------------
# schema + round trip
# --------------------------------------------------------------------------

def test_classify_metric_directions():
    assert common.classify_metric("x/exposed_comm") == "lower"
    assert common.classify_metric("x/t_total_ms") == "lower"
    assert common.classify_metric("x/latency", "us") == "lower"
    assert common.classify_metric("x/scaling_eff") == "higher"
    assert common.classify_metric("x/reduction") == "higher"
    assert common.classify_metric("x/throughput") == "higher"
    assert common.classify_metric("x/wire_bytes") is None


def test_ledger_roundtrip(tmp_path):
    led = _sample_ledger()
    path = led.write(tmp_path)
    assert os.path.basename(path) == "BENCH_bench_demo.json"
    with open(path) as fh:
        rec = json.load(fh)
    common.validate_ledger(rec)          # no raise
    assert rec["schema_version"] == common.SCHEMA_VERSION
    assert rec["module"] == "bench_demo"
    assert rec["git_sha"]
    assert isinstance(rec["device_count"], int)
    by_name = {m["name"]: m for m in rec["metrics"]}
    assert by_name["demo/exposed_comm_s"]["better"] == "lower"
    assert by_name["demo/exposed_comm_s"]["stable"] is True
    assert by_name["demo/scaling_eff"]["better"] == "higher"
    assert by_name["demo/step/us_per_call"]["stable"] is False
    assert by_name["demo/algo"]["value"] == "hier"
    assert by_name["demo/wire_bytes"]["better"] is None

    loaded = perf_table.load_ledgers(str(tmp_path))
    assert loaded == {"bench_demo": rec}


@pytest.mark.parametrize("mutate,err", [
    (lambda r: r.pop("module"), "module"),
    (lambda r: r.pop("metrics"), "metrics"),
    (lambda r: r.update(schema_version=common.SCHEMA_VERSION + 1), "schema"),
    (lambda r: r["metrics"].append({"value": 1.0}), "malformed"),
    (lambda r: r["metrics"][0].update(better="sideways"), "better"),
])
def test_validate_ledger_rejects(mutate, err):
    rec = _sample_ledger().to_record()
    mutate(rec)
    with pytest.raises(ValueError, match=err):
        common.validate_ledger(rec)


def test_emit_records_parsed_metrics(capsys):
    common.start_ledger("bench_emit_test")
    try:
        common.emit("k/row", 12.5,
                    "reduction=1.90x;algo=flat;ok=True;t_ms=3.5;"
                    "eff=0.93;note_free_text")
        led = common.current_ledger()
    finally:
        common._ACTIVE = None
    out = capsys.readouterr().out
    assert "k/row,12.500,reduction=1.90x" in out     # CSV unchanged
    by_name = {m.name: m for m in led.metrics}
    assert by_name["k/row/us_per_call"].value == 12.5
    assert by_name["k/row/us_per_call"].stable is False
    assert by_name["k/row/reduction"].value == pytest.approx(1.90)
    assert by_name["k/row/reduction"].better == "higher"
    assert by_name["k/row/algo"].value == "flat"
    assert by_name["k/row/ok"].value == 1.0
    assert by_name["k/row/t_ms"].value == pytest.approx(3.5)
    assert by_name["k/row/t_ms"].better == "lower"
    assert by_name["k/row/eff"].better == "higher"
    assert "k/row/note_free_text" not in by_name     # no k=v -> not a metric


def test_run_with_ledger_writes_artifact_on_failure(tmp_path, capsys):
    with pytest.raises(ZeroDivisionError):
        common.run_with_ledger("bench_boom", lambda: 1 / 0,
                               out_dir=str(tmp_path))
    # artifact still written (ci must see partial results of a dead run)
    assert (tmp_path / "BENCH_bench_boom.json").exists()
    capsys.readouterr()


def test_time_fn_smoke():
    # S1 regression guard: warmup results are blocked on before the timed
    # region; warmup=0 must not crash either
    assert common.time_fn(lambda: 123, iters=2) >= 0.0
    assert common.time_fn(lambda: 123, iters=1, warmup=0) >= 0.0


# --------------------------------------------------------------------------
# diff gate
# --------------------------------------------------------------------------

def _write_pair(tmp_path, old_exposed, new_exposed):
    old_dir, new_dir = tmp_path / "old", tmp_path / "new"
    old_dir.mkdir(), new_dir.mkdir()
    _sample_ledger(exposed=old_exposed).write(old_dir)
    _sample_ledger(exposed=new_exposed).write(new_dir)
    return str(old_dir), str(new_dir)


def test_diff_identical_ledgers_clean(tmp_path):
    led = _sample_ledger()
    for d in ("old", "new"):
        (tmp_path / d).mkdir()
        led.write(tmp_path / d)
    rc = perf_table.main(["--diff", str(tmp_path / "old"),
                          str(tmp_path / "new")])
    assert rc == 0


def test_diff_detects_injected_regression(tmp_path, capsys):
    old_dir, new_dir = _write_pair(tmp_path, 10.0, 12.0)   # +20% exposed
    rc = perf_table.main(["--diff", old_dir, new_dir, "--tol", "0.05"])
    assert rc == 1
    out = capsys.readouterr().out
    assert "REGRESSED" in out and "demo/exposed_comm_s" in out


def test_diff_improvement_is_not_regression(tmp_path, capsys):
    old_dir, new_dir = _write_pair(tmp_path, 10.0, 8.0)    # -20% exposed
    rc = perf_table.main(["--diff", old_dir, new_dir, "--tol", "0.05"])
    assert rc == 0
    assert "IMPROVED" in capsys.readouterr().out


def test_diff_higher_better_regression(tmp_path):
    old_dir, new_dir = (tmp_path / "old", tmp_path / "new")
    old_dir.mkdir(), new_dir.mkdir()
    for d, eff in ((old_dir, 0.95), (new_dir, 0.80)):
        led = common.Ledger("bench_eff")
        led.record("eff/scaling_eff", eff)
        led.write(d)
    assert perf_table.main(["--diff", str(old_dir), str(new_dir)]) == 1


def test_diff_unstable_metric_warns_not_gates(tmp_path, capsys):
    old_dir, new_dir = (tmp_path / "old", tmp_path / "new")
    old_dir.mkdir(), new_dir.mkdir()
    for d, us in ((old_dir, 100.0), (new_dir, 300.0)):     # 3x wall clock
        led = common.Ledger("bench_wall")
        led.record("wall/us_per_call", us, unit="us", stable=False)
        led.write(d)
    assert perf_table.main(["--diff", str(old_dir), str(new_dir)]) == 0
    assert "warn-only" in capsys.readouterr().out
    # ... unless an explicit wall-clock tolerance is requested
    assert perf_table.main(["--diff", str(old_dir), str(new_dir),
                            "--time-tol", "0.5"]) == 1
    capsys.readouterr()


def test_diff_string_change_warns(tmp_path, capsys):
    old_dir, new_dir = (tmp_path / "old", tmp_path / "new")
    old_dir.mkdir(), new_dir.mkdir()
    for d, algo in ((old_dir, "flat"), (new_dir, "hier")):
        led = common.Ledger("bench_route")
        led.record("route/algo", algo)
        led.write(d)
    assert perf_table.main(["--diff", str(old_dir), str(new_dir)]) == 0
    assert "value changed" in capsys.readouterr().out


def test_diff_warn_only_flag(tmp_path, capsys):
    old_dir, new_dir = _write_pair(tmp_path, 10.0, 12.0)
    rc = perf_table.main(["--diff", old_dir, new_dir, "--warn-only"])
    assert rc == 0
    capsys.readouterr()


def test_load_all_skips_corrupt_files(tmp_path, capsys):
    (tmp_path / "BENCH_corrupt.json").write_text("{nope")
    _sample_ledger().write(tmp_path)
    loaded = perf_table.load_ledgers(str(tmp_path))
    assert list(loaded) == ["bench_demo"]
    assert "skipping" in capsys.readouterr().err
