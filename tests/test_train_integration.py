"""End-to-end training: losses decrease, comm modes agree numerically."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import compat
from repro.configs import registry
from repro.core.planner import Planner
from repro.data import pipeline
from repro.models.transformer import Batch, Model
from repro.optim import optimizers as opt_lib
from repro.train import trainer as tr


@pytest.fixture(scope="module")
def mesh():
    return compat.make_mesh((1, 1), ("data", "model"),
                            axis_types=(compat.AxisType.Auto,) * 2)


def _train(mesh, comm, steps=25, arch="yi-6b", seed=0):
    cfg = registry.get_smoke_config(arch)
    model = Model(cfg)
    opt = opt_lib.adamw(3e-3)
    planner = Planner(mesh=mesh)
    dcfg = pipeline.DataConfig(vocab=cfg.vocab, seq_len=48, global_batch=4,
                               seed=seed)
    with compat.set_mesh(mesh):
        state = tr.make_train_state(model, opt, jax.random.PRNGKey(seed))
        step = jax.jit(tr.make_train_step(model, opt, mesh, planner, comm))
        losses = []
        for raw in pipeline.iterate(dcfg, steps):
            batch = Batch(tokens=jnp.asarray(raw["tokens"]),
                          labels=jnp.asarray(raw["labels"]))
            state, metrics = step(state, batch)
            losses.append(float(metrics["loss"]))
    return losses, state


def test_loss_decreases_gspmd(mesh):
    losses, _ = _train(mesh, tr.CommConfig(mode="gspmd"))
    assert losses[-1] < losses[0] - 0.3, losses


def test_mlsl_fp32_matches_gspmd_exactly(mesh):
    """With an fp32 wire and one rank, the MLSL data path must be numerically
    identical to the GSPMD baseline."""
    l1, s1 = _train(mesh, tr.CommConfig(mode="gspmd", prioritize=True),
                    steps=5)
    l2, s2 = _train(mesh, tr.CommConfig(mode="mlsl", wire="fp32",
                                        prioritize=True), steps=5)
    np.testing.assert_allclose(l1, l2, rtol=1e-6)
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32), rtol=2e-5,
            atol=1e-6),
        s1.params, s2.params)


@pytest.mark.parametrize("wire,ef", [("bf16", False), ("int8", False),
                                     ("int8", True)])
def test_low_precision_wires_still_train(mesh, wire, ef):
    losses, _ = _train(mesh, tr.CommConfig(mode="mlsl", wire=wire,
                                           error_feedback=ef))
    assert losses[-1] < losses[0] - 0.3, (wire, ef, losses)


def test_prioritization_changes_schedule_not_math(mesh):
    l1, s1 = _train(mesh, tr.CommConfig(mode="mlsl", prioritize=True),
                    steps=4)
    l2, s2 = _train(mesh, tr.CommConfig(mode="mlsl", prioritize=False),
                    steps=4)
    np.testing.assert_allclose(l1, l2, rtol=1e-6)


def test_moe_arch_trains(mesh):
    losses, _ = _train(mesh, tr.CommConfig(), arch="arctic-480b", steps=15)
    assert losses[-1] < losses[0] - 0.15, losses


def test_ssm_arch_trains(mesh):
    # the SSD mixer has a slow first ~20 steps on this toolchain (flat loss,
    # then steady descent); 40 steps clears the threshold with margin
    losses, _ = _train(mesh, tr.CommConfig(), arch="mamba2-2.7b", steps=40)
    assert losses[-1] < losses[0] - 0.15, losses
