"""MLSL collectives API: wire formats and single-rank semantics (multi-rank
equivalence is covered by tests/test_multidevice.py in a subprocess)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.core import collectives as cl


def _run1(fn, x, mesh11):
    # jit-wrapped, as in the trainer: inside jit the partial-manual shard_map
    # accepts replicated specs with check_vma=False.
    return jax.jit(compat.shard_map(fn, mesh=mesh11, in_specs=P(),
                                    out_specs=P(), axis_names={"data"},
                                    check_vma=False))(x)


@pytest.mark.parametrize("wire", cl.WIRES)
def test_allreduce_identity_on_one_rank(wire, mesh11):
    x = jax.random.normal(jax.random.PRNGKey(0), (1000,)) * 0.01
    y = _run1(lambda u: cl.allreduce(u, ("data",), wire=wire), x, mesh11)
    # int8 error = bf16 reduce-scatter leg (~2^-8 rel) + int8 block
    # quantization (~amax/254)
    tol = {"fp32": 1e-7, "bf16": 1e-2, "int8": 1e-2}[wire]
    np.testing.assert_allclose(np.asarray(y), np.asarray(x),
                               rtol=tol, atol=tol * float(jnp.max(jnp.abs(x))))


def test_allreduce_ef_residual_tracks_error(mesh11):
    x = jax.random.normal(jax.random.PRNGKey(1), (2048,)) * 1e-3
    res0 = jnp.zeros(cl.ef_residual_shape(x.size, 1), jnp.float32)

    def f(u, r):
        return cl.allreduce_ef(u, r, ("data",))

    y, res = jax.jit(compat.shard_map(f, mesh=mesh11, in_specs=(P(), P()),
                                      out_specs=(P(), P()),
                                      axis_names={"data"},
                                      check_vma=False))(x, res0)
    # y + residual == bf16(x): the residual holds exactly the quantization
    # error of the bf16-wire reduce-scatter shard
    xb = np.asarray(x.astype(jnp.bfloat16).astype(jnp.float32))
    np.testing.assert_allclose(np.asarray(y[: x.size]) + np.asarray(
        res[: x.size]), xb, rtol=1e-5, atol=1e-8)


def test_wire_bytes_ordering():
    assert cl.wire_bytes_per_elem("fp32") > cl.wire_bytes_per_elem("bf16") \
        > cl.wire_bytes_per_elem("int8")


def test_broadcast_root_semantics(mesh11):
    x = jnp.arange(8.0)
    y = _run1(lambda u: cl.broadcast(u, ("data",), root=0), x, mesh11)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(x))


def test_comm_facade(mesh11):
    comm = cl.Comm(mesh=mesh11, data_axes=("data",))
    assert comm.data_parallel_size == 1
    assert comm.model_parallel_size == 1
    y = jax.jit(lambda v: comm.run(lambda u: cl.allreduce(u, ("data",)),
                                   P(), P(), v))(jnp.ones((4,)))
    np.testing.assert_array_equal(np.asarray(y), np.ones(4))
