"""Executed hybrid (data x model) parallelism: the C2C chooser's verdicts
materialized as real tensor-parallel sharding on the ("node"=2, "local"=4)
mesh — f/g activation collectives, plan gating and clean DP fallback, the
engine's per-bucket reduce axes, and step-for-step equivalence with pure DP.
"""

from __future__ import annotations

import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.configs import registry
from repro.core import c2c, collectives as cl, hw, planner as pl
from repro.data import pipeline
from repro.models.transformer import Batch, Model
from repro.optim import optimizers as opt_lib
from repro.train import trainer as tr

AXES = {"node", "local"}


# ---------------------------------------------------------------------------
# f/g activation collectives
# ---------------------------------------------------------------------------

def test_fg_ops_match_dense_reference(mesh8):
    """Column-sharded w1 / row-sharded w2 through tp_replicate (f) and
    tp_psum (g) reproduces the dense forward AND all gradients — the
    transpose-correctness property the custom_vjp pair exists for."""
    d, h = 8, 16
    x = jax.random.normal(jax.random.PRNGKey(0), (4, d), jnp.float32)
    w1 = 0.1 * jax.random.normal(jax.random.PRNGKey(1), (d, h), jnp.float32)
    w2 = 0.1 * jax.random.normal(jax.random.PRNGKey(2), (h, d), jnp.float32)

    def dense_loss(w1, w2, x):
        return jnp.sum(jax.nn.relu(x @ w1) @ w2)

    def inner(w1, w2, x):
        # grads taken INSIDE the manual region, exactly like the trainer:
        # the f/g pair routes the activation cotangents between ranks
        def loss_fn(w1, w2, x):
            xr = cl.tp_replicate(x, "local")
            y = cl.tp_psum(jax.nn.relu(xr @ w1) @ w2, "local")
            return jnp.sum(y)
        return jax.value_and_grad(loss_fn, argnums=(0, 1, 2))(w1, w2, x)

    w_specs = (P(None, "local"), P("local", None), P())
    sharded = compat.shard_map(inner, mesh=mesh8, in_specs=w_specs,
                               out_specs=(P(), w_specs), axis_names=AXES,
                               check_vma=False)

    with compat.set_mesh(mesh8):
        loss, (g1, g2, gx) = sharded(w1, w2, x)
    ref = dense_loss(w1, w2, x)
    d1, d2, dx = jax.grad(dense_loss, argnums=(0, 1, 2))(w1, w2, x)
    np.testing.assert_allclose(np.asarray(loss), np.asarray(ref), atol=1e-4)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(d1), atol=1e-4)
    np.testing.assert_allclose(np.asarray(g2), np.asarray(d2), atol=1e-4)
    np.testing.assert_allclose(np.asarray(gx), np.asarray(dx), atol=1e-4)


def test_tp_psum_scatter_matches_tp_psum(mesh8):
    """The bandwidth-shaped psum (reduce_scatter + all_gather over the
    trailing dim) is numerically the plain psum."""
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 8), jnp.float32)

    def run(op):
        def inner(v):
            # make per-rank values distinct so the reduction is exercised
            r = jax.lax.axis_index("local").astype(jnp.float32)
            return op(v * (1.0 + r), "local")
        return compat.shard_map(inner, mesh=mesh8, in_specs=P(),
                                out_specs=P(), axis_names=AXES,
                                check_vma=False)(x)

    with compat.set_mesh(mesh8):
        a = run(cl.tp_psum)
        b = run(cl.tp_psum_scatter)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)


# ---------------------------------------------------------------------------
# plan gating: chooser verdict -> executed sharding
# ---------------------------------------------------------------------------

def _amesh():
    return compat.abstract_mesh((2, 4), ("node", "local"))


def test_plan_hybrid_verdicts_match_execution():
    cfg = registry.get_smoke_config("yi-6b")
    plan = pl.plan_hybrid(cfg, _amesh(), batch=8, seq=64)
    assert plan.tp == 4 and plan.dp == 2 and plan.data_axes == ("node",)
    blk = plan.layer("p0_attn")
    assert blk.choice.strategy in (c2c.Strategy.HYBRID, c2c.Strategy.MODEL)
    assert blk.model_parallel and blk.reason == ""
    # chooser sends embed/head data-parallel or they are gated off — either
    # way they must not execute model-parallel
    for name in ("embed", "head"):
        lp = plan.layer(name)
        assert not lp.model_parallel
        assert lp.reason in ("chooser-data",) or \
            lp.reason.startswith("unsupported-kind")
    assert plan.any_model_parallel


def test_hybrid_planner_emits_sharded_specs():
    """The chooser's model-parallel verdict becomes actual PartitionSpecs:
    attention projections shard over "local", everything else replicates."""
    cfg = registry.get_smoke_config("yi-6b")
    planner = pl.make_hybrid_planner(_amesh(), cfg, batch=8, seq=64)
    specs = planner.tree_specs(Model(cfg).param_defs(),
                               stacked_paths=Model.stacked_path)
    attn = specs["blocks"]["p0_attn"]["attn"]
    assert attn["wq"] == P(None, None, "local")      # stacked: leading layer
    assert attn["wo"] == P(None, "local", None)
    mlp = specs["blocks"]["p0_attn"]["mlp"]
    assert mlp["w1"] == P(None, None, "local")
    assert mlp["w2"] == P(None, "local", None)
    assert specs["embed"] == P(None, None)
    assert specs["head"] == P(None, None)


def test_group_indivisible_falls_back_to_dp():
    cfg = registry.get_smoke_config("yi-6b")
    for g in (2, 3):
        plan = pl.plan_hybrid(cfg, _amesh(), batch=8, seq=64, group_size=g)
        assert not plan.any_model_parallel, g
        assert any(lp.reason.startswith("group-indivisible")
                   for lp in plan.layers), g
        planner = pl.make_hybrid_planner(_amesh(), cfg, batch=8, seq=64,
                                         group_size=g)
        specs = planner.tree_specs(Model(cfg).param_defs(),
                                   stacked_paths=Model.stacked_path)
        for spec in jax.tree_util.tree_leaves(
                specs, is_leaf=lambda s: isinstance(s, P)):
            assert all(ax is None for ax in spec), (g, spec)


def _indivisible_heads_cfg():
    cfg = registry.get_smoke_config("yi-6b")
    return dataclasses.replace(
        cfg, attn=dataclasses.replace(cfg.attn, n_heads=2, n_kv=2))


def test_indivisible_heads_fall_back_to_dp():
    plan = pl.plan_hybrid(_indivisible_heads_cfg(), _amesh(), batch=8, seq=64)
    assert not plan.any_model_parallel
    lp = plan.layer("p0_attn")
    if lp.choice.group_size > 1:          # chooser wanted the group anyway
        assert lp.reason.startswith("indivisible-heads")


def test_c2c_layer_names_match_param_tree():
    for arch in ("yi-6b", "chatglm3-6b", "deepseek-7b"):
        cfg = registry.get_smoke_config(arch)
        defs = Model(cfg).param_defs()
        valid = {"embed", "head"} | set(defs.get("blocks", {})) \
            | set(defs.get("tail", {}))
        for spec in c2c.layers_from_model_config(cfg, 64):
            assert spec.name in valid, (arch, spec.name)


# ---------------------------------------------------------------------------
# engine: per-bucket reduce axes
# ---------------------------------------------------------------------------

def _hybrid_engine(mesh8, comm=None, cfg=None):
    cfg = cfg or registry.get_smoke_config("yi-6b")
    planner = pl.make_hybrid_planner(mesh8, cfg, batch=8, seq=32)
    comm = comm or tr.CommConfig(mode="mlsl", hier=True)
    return tr.make_comm_engine(Model(cfg), mesh8, planner, comm)


def test_engine_hybrid_bucket_axes(mesh8):
    engine = _hybrid_engine(mesh8)
    plan = engine.plan
    assert plan.tp_axis == "local" and plan.tp == 4
    assert len(plan.bucket_axes) == plan.n_buckets
    # both flavors exist: sharded buckets reduce over the node axis only,
    # replicated ones keep the full two-level (node, local) reduction
    assert set(plan.bucket_axes) == {("node",), ("node", "local")}
    assert engine.tp is not None and engine.tp.axis == "local"
    # model-sharded buckets cannot take the two-level route
    for axes, algo in zip(plan.bucket_axes, plan.algos):
        if axes == ("node",):
            assert algo == pl.ALGO_FLAT


def test_engine_hybrid_rejects_error_feedback(mesh8):
    comm = tr.CommConfig(mode="mlsl", hier=True, wire="int8",
                         error_feedback=True)
    with pytest.raises(ValueError, match="error feedback"):
        _hybrid_engine(mesh8, comm=comm)


def test_trainer_hybrid_requires_mlsl(mesh8):
    cfg = registry.get_smoke_config("yi-6b")
    planner = pl.make_hybrid_planner(mesh8, cfg, batch=8, seq=32)
    with pytest.raises(ValueError, match="mlsl"):
        tr.make_train_step(Model(cfg), opt_lib.adamw(1e-3), mesh8, planner,
                           tr.CommConfig(mode="gspmd"))


# ---------------------------------------------------------------------------
# executed training: hybrid == pure DP, step for step
# ---------------------------------------------------------------------------

def _train(mesh, cfg, planner, steps=2, seq=16, batch=8):
    model = Model(cfg)
    opt = opt_lib.make_optimizer("sgd", 0.1)
    comm = tr.CommConfig(mode="mlsl", hier=True)
    dcfg = pipeline.DataConfig(vocab=cfg.vocab, seq_len=seq,
                               global_batch=batch, seed=3)
    with compat.set_mesh(mesh):
        state = tr.make_train_state(model, opt, jax.random.PRNGKey(0))
        step = jax.jit(tr.make_train_step(model, opt, mesh, planner, comm))
        metrics = []
        for raw in pipeline.iterate(dcfg, steps):
            b = Batch(tokens=jnp.asarray(raw["tokens"]),
                      labels=jnp.asarray(raw["labels"]))
            state, m = step(state, b)
            metrics.append((float(m["loss"]), float(m["grad_norm"])))
    return metrics, state


def _assert_same_training(cfg, mesh8, atol_loss=5e-4, atol_params=1e-4):
    dp_m, dp_state = _train(mesh8, cfg, pl.Planner(mesh=mesh8))
    hy_m, hy_state = _train(mesh8, cfg,
                            pl.make_hybrid_planner(mesh8, cfg, batch=8,
                                                   seq=16))
    for (dl, dg), (hl, hg) in zip(dp_m, hy_m):
        assert np.isfinite(hl) and np.isfinite(hg)
        assert abs(dl - hl) < atol_loss, (dp_m, hy_m)
    dp_leaves = jax.tree_util.tree_leaves(dp_state.params)
    hy_leaves = jax.tree_util.tree_leaves(hy_state.params)
    assert len(dp_leaves) == len(hy_leaves)
    for a, b in zip(dp_leaves, hy_leaves):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=atol_params)


def test_hybrid_step_matches_dp_with_sharded_layers(mesh8):
    """THE tentpole equivalence: the chooser sends p0_attn model-parallel,
    the weights really shard over "local", and two executed training steps
    land where pure DP-8 lands at the same global batch."""
    cfg = registry.get_smoke_config("yi-6b")
    planner = pl.make_hybrid_planner(mesh8, cfg, batch=8, seq=16)
    assert planner.hybrid.any_model_parallel
    _assert_same_training(cfg, mesh8)


def test_hybrid_step_matches_dp_on_fallback_config(mesh8):
    """When every layer is gated back to DP (indivisible heads) the hybrid
    machinery still runs — through the same manual region — and must be
    exactly a DP step on replicated weights."""
    cfg = _indivisible_heads_cfg()
    planner = pl.make_hybrid_planner(mesh8, cfg, batch=8, seq=16)
    assert not planner.hybrid.any_model_parallel
    _assert_same_training(cfg, mesh8)


# ---------------------------------------------------------------------------
# modeled exposed-comm win
# ---------------------------------------------------------------------------

def test_modeled_hybrid_beats_pure_dp():
    cfg = registry.get_smoke_config("yi-6b")
    plan = pl.plan_hybrid(cfg, _amesh(), batch=8, seq=64)
    layers = c2c.layers_from_model_config(cfg, 64)
    for topo in (hw.CLOUD_10G, hw.HPC_OPA):
        cm = pl.model_hybrid_comm(plan, layers, batch=8, nodes=plan.dp,
                                  topo=topo)
        assert cm.t_hybrid < cm.t_dp_flat, topo.name
        assert cm.reduction_vs_flat > 1.0
        # the hybrid fabric traffic is strictly smaller than full-gradient DP
        assert cm.hybrid_grad_bytes < cm.dp_grad_bytes
