"""Hierarchical two-level collectives on the 8-virtual-device harness, plus
the compat shim they sit on.

The mesh8 fixture (conftest) factors the 8 fake host devices into
("node"=2, "local"=4): "local" stands for the fast intra-node link, "node"
for the slow fabric. The tentpole claims verified here:

  * fp32 legs: the two-level decomposition is BIT-EXACT against the
    per-axis psum reference (psum over local, then node -- the same
    reduction tree) and within float32 ulp of the flat one-shot
    ``lax.psum`` over both axes (XLA's 8-rank allreduce associates in its
    own internal order, so last-ulp equality with it is not defined);
  * lossy legs (bf16 intra, int8 fabric, error feedback) stay within their
    wire tolerances;
  * the Comm facade, bucket-scheduler routing, and per-level cost model
    agree on when the hierarchy pays.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax import lax
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.core import collectives as cl
from repro.core import hier, hw, planner, scheduler, simulator as sim

DSPEC = P((hier.NODE_AXIS, hier.LOCAL_AXIS))


def _run8(fn, mesh8, *args, in_specs=None, out_specs=P()):
    """Run fn manually over both data axes of the (2, 4) mesh."""
    if in_specs is None:
        in_specs = tuple(DSPEC for _ in args)
    return jax.jit(compat.shard_map(fn, mesh=mesh8, in_specs=in_specs,
                                    out_specs=out_specs))(*args)


@pytest.fixture(scope="module")
def x8():
    return jax.random.normal(jax.random.PRNGKey(0), (8, 4097),
                             jnp.float32) * 1e-3


def _psum_ref(mesh8, x8):
    return np.asarray(_run8(
        lambda u: lax.psum(u[0], (hier.NODE_AXIS, hier.LOCAL_AXIS)),
        mesh8, x8))


def test_hier_fp32_bit_exact_vs_per_axis_psum(mesh8, x8):
    """fp32 legs == the controlled two-level reduction tree, bitwise."""
    seq = np.asarray(_run8(
        lambda u: lax.psum(lax.psum(u[0], hier.LOCAL_AXIS), hier.NODE_AXIS),
        mesh8, x8))
    got = np.asarray(_run8(lambda u: hier.hier_allreduce(u[0]), mesh8, x8))
    np.testing.assert_array_equal(got, seq)


def test_hier_fp32_matches_flat_psum_to_ulp(mesh8, x8):
    ref = _psum_ref(mesh8, x8)
    got = np.asarray(_run8(lambda u: hier.hier_allreduce(u[0]), mesh8, x8))
    # 8-way fp32 sums of ~1e-3 values: a few ulp of headroom
    np.testing.assert_allclose(got, ref, rtol=1e-6, atol=1e-8)


def test_hier_fp32_matches_flat_collectives_allreduce(mesh8, x8):
    flat = np.asarray(_run8(
        lambda u: cl.allreduce(u[0], (hier.NODE_AXIS, hier.LOCAL_AXIS)),
        mesh8, x8))
    got = np.asarray(_run8(lambda u: hier.hier_allreduce(u[0]), mesh8, x8))
    np.testing.assert_allclose(got, flat, rtol=1e-6, atol=1e-8)


@pytest.mark.parametrize("spec,tol", [
    (hier.HierSpec(wire_intra="bf16"), 3e-2),
    (hier.HierSpec(wire_intra="bf16", wire_inter="bf16"), 3e-2),
    (hier.HierSpec(wire_intra="bf16", wire_inter="int8"), 2e-2),
    (hier.HierSpec(wire_inter="int8"), 2e-2),
])
def test_hier_lossy_legs_within_wire_tolerance(mesh8, x8, spec, tol):
    ref = _psum_ref(mesh8, x8)
    got = np.asarray(_run8(
        lambda u, s=spec: hier.hier_allreduce(u[0], s), mesh8, x8))
    err = np.max(np.abs(got - ref)) / np.max(np.abs(ref))
    assert err < tol, (spec, err)


def test_hier_mean_divides_by_total_ranks(mesh8, x8):
    ref = _psum_ref(mesh8, x8) / 8.0
    got = np.asarray(_run8(
        lambda u: hier.hier_allreduce(u[0], mean=True), mesh8, x8))
    np.testing.assert_allclose(got, ref, rtol=1e-6, atol=1e-9)


def test_hier_error_feedback_roundtrip(mesh8, x8):
    spec = hier.HierSpec(wire_intra="bf16", wire_inter="int8",
                         error_feedback=True)
    shard = hier.ef_residual_shape(x8[0].size, local=4, node=2)
    res0 = jnp.zeros((shard[0] * 8,), jnp.float32)  # global view, 8 ranks

    def f(u, r):
        return hier.hier_allreduce_ef(u[0], r, spec)

    y, res = jax.jit(compat.shard_map(
        f, mesh=mesh8, in_specs=(DSPEC, DSPEC),
        out_specs=(P(), DSPEC)))(x8, res0)
    ref = _psum_ref(mesh8, x8)
    err = np.max(np.abs(np.asarray(y) - ref)) / np.max(np.abs(ref))
    assert err < 2e-2, err
    # the residual carries the (nonzero) per-rank quantization error
    assert res.shape == res0.shape
    assert float(jnp.max(jnp.abs(res))) > 0


def test_hier_spec_validation():
    with pytest.raises(ValueError):
        hier.HierSpec(wire_intra="int8")           # lossy wire can't reduce
    with pytest.raises(ValueError):
        hier.HierSpec(error_feedback=True)         # EF needs int8 fabric
    with pytest.raises(ValueError):
        hier.HierSpec(wire_inter="fp8")            # unknown wire


# --------------------------------------------------------------------------
# Comm facade
# --------------------------------------------------------------------------

def test_comm_hierarchical_facade(mesh8, x8):
    comm = cl.Comm(mesh=mesh8, data_axes=(hier.NODE_AXIS, hier.LOCAL_AXIS),
                   model_axis=None, node_axis=hier.NODE_AXIS,
                   local_axis=hier.LOCAL_AXIS)
    assert comm.hierarchical
    assert comm.node_size == 2 and comm.local_size == 4
    assert comm.data_parallel_size == 8

    ref = _psum_ref(mesh8, x8)
    y = jax.jit(lambda v: comm.run(
        lambda u: comm.allreduce(u[0]), DSPEC, P(), v))(x8)
    np.testing.assert_allclose(np.asarray(y), ref, rtol=1e-6, atol=1e-8)
    # lossy fabric leg defaults the intra legs to bf16
    y8 = jax.jit(lambda v: comm.run(
        lambda u: comm.allreduce(u[0], wire="int8"), DSPEC, P(), v))(x8)
    err = np.max(np.abs(np.asarray(y8) - ref)) / np.max(np.abs(ref))
    assert err < 2e-2, err


def test_comm_flat_mesh_stays_flat(mesh11):
    comm = cl.Comm(mesh=mesh11, data_axes=("data",))
    assert not comm.hierarchical
    y = jax.jit(lambda v: comm.run(
        lambda u: comm.allreduce(u), P(), P(), v))(jnp.ones((8,)))
    np.testing.assert_array_equal(np.asarray(y), np.ones(8))


# --------------------------------------------------------------------------
# cost model: planner choice, scheduler routing, simulator integration
# --------------------------------------------------------------------------

def test_hier_time_beats_flat_for_bulk_messages():
    for topo in (hw.CLOUD_10G, hw.HPC_OPA):
        t_flat = hw.flat_allreduce_time(100e6, 16, topo)
        t_hier = hw.hier_allreduce_time(100e6, 16, topo)
        assert t_hier < t_flat, topo.name


def test_choose_allreduce_algo_degenerate_hierarchies():
    assert planner.choose_allreduce_algo(1e6, nodes=1, topo=hw.CLOUD_10G) \
        == planner.ALGO_FLAT
    flat_topo = hw.Topology("flat", intra=hw.SHM_LINK, inter=hw.ETH_10G,
                            local_size=1)
    assert planner.choose_allreduce_algo(1e8, nodes=16, topo=flat_topo) \
        == planner.ALGO_FLAT


def test_choose_allreduce_algo_prefers_hier_for_bulk():
    assert planner.choose_allreduce_algo(1e8, nodes=16, topo=hw.CLOUD_10G) \
        == planner.ALGO_HIER


def test_scheduler_routes_bulk_buckets_hierarchically():
    tree = {"first": jnp.zeros((4,)), "bulk": jnp.zeros((64, 1024, 256))}
    plan = scheduler.plan_buckets(tree, bucket_bytes=1 << 16)
    routes = scheduler.route_buckets(plan, hw.CLOUD_10G, nodes=16)
    assert len(routes) == len(plan.buckets)
    assert all(r in (planner.ALGO_FLAT, planner.ALGO_HIER) for r in routes)
    by_size = {b.n_elems: r for b, r in zip(plan.buckets, routes)}
    assert by_size[64 * 1024 * 256] == planner.ALGO_HIER


def test_simulator_hier_topology_improves_iteration():
    layers = [sim.SimLayer(f"l{i}", fwd_time=1e-3, bwd_time=2e-3,
                           wgrad_bytes=50e6) for i in range(8)]
    flat = sim.simulate_iteration(layers, 16, hw.ETH_10G,
                                  topo=hw.CLOUD_10G, comm_algo="flat")
    hier_st = sim.simulate_iteration(layers, 16, hw.ETH_10G,
                                     topo=hw.CLOUD_10G, comm_algo="hier")
    auto = sim.simulate_iteration(layers, 16, hw.ETH_10G,
                                  topo=hw.CLOUD_10G, comm_algo="auto")
    assert hier_st.total_time < flat.total_time
    assert auto.total_time <= min(hier_st.total_time, flat.total_time) + 1e-12
    # hierarchy lifts weak-scaling efficiency at fixed node count
    eff_flat = sim.scaling_efficiency(layers, 16, hw.ETH_10G,
                                      topo=hw.CLOUD_10G, comm_algo="flat")
    eff_hier = sim.scaling_efficiency(layers, 16, hw.ETH_10G,
                                      topo=hw.CLOUD_10G, comm_algo="hier")
    assert eff_hier > eff_flat


def test_wire_bytes_per_level_accounting():
    spec = hier.HierSpec(wire_intra="bf16", wire_inter="int8")
    wb = hier.hier_wire_bytes_per_elem(spec, local=4, node=2)
    flat = hier.flat_wire_bytes_per_elem("int8")
    # the fabric sees exactly 1/local of the flat int8 volume
    np.testing.assert_allclose(wb.inter, flat.inter / 4)
    assert wb.total == wb.intra + wb.inter
    # degenerate levels carry nothing
    assert hier.hier_wire_bytes_per_elem(spec, local=1, node=2).intra == 0.0
    assert hier.hier_wire_bytes_per_elem(spec, local=4, node=1).inter == 0.0


# --------------------------------------------------------------------------
# end-to-end: trainer routes buckets through the two-level path
# --------------------------------------------------------------------------

def test_trainer_hier_matches_flat_mlsl(mesh8):
    from repro.configs import registry
    from repro.core.planner import Planner
    from repro.data import pipeline
    from repro.models.transformer import Batch, Model
    from repro.optim import optimizers as opt_lib
    from repro.train import trainer as tr

    cfg = registry.get_smoke_config("yi-6b")
    model = Model(cfg)
    opt = opt_lib.adamw(3e-3)
    pln = Planner(mesh=mesh8)
    dcfg = pipeline.DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=8)
    results = {}
    for name, comm in (("flat", tr.CommConfig(mode="mlsl")),
                       ("hier", tr.CommConfig(mode="mlsl", hier=True))):
        with compat.set_mesh(mesh8):
            state = tr.make_train_state(model, opt, jax.random.PRNGKey(0))
            step = jax.jit(tr.make_train_step(model, opt, mesh8, pln, comm))
            for raw in pipeline.iterate(dcfg, 3):
                batch = Batch(tokens=jnp.asarray(raw["tokens"]),
                              labels=jnp.asarray(raw["labels"]))
                state, m = step(state, batch)
        results[name] = (float(m["loss"]), state.params)
    # fp32 legs: same math up to reduction-order ulp; Adam amplifies noise
    assert abs(results["flat"][0] - results["hier"][0]) < 1e-4, results
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32),
            rtol=1e-2, atol=5e-4),
        results["flat"][1], results["hier"][1])


def test_trainer_topo_routing_trains(mesh8):
    """CommConfig(topo=...) routes each bucket flat-vs-hier via the cost
    model; the result must still be a correct (converging) fp32 reduction."""
    from repro.configs import registry
    from repro.core.planner import Planner
    from repro.data import pipeline
    from repro.models.transformer import Batch, Model
    from repro.optim import optimizers as opt_lib
    from repro.train import trainer as tr

    cfg = registry.get_smoke_config("yi-6b")
    model = Model(cfg)
    opt = opt_lib.adamw(3e-3)
    comm = tr.CommConfig(mode="mlsl", hier=True, topo="xeon-shm-10gbe")
    dcfg = pipeline.DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=8)
    with compat.set_mesh(mesh8):
        state = tr.make_train_state(model, opt, jax.random.PRNGKey(0))
        step = jax.jit(tr.make_train_step(model, opt, mesh8,
                                          Planner(mesh=mesh8), comm))
        losses = []
        for raw in pipeline.iterate(dcfg, 3):
            batch = Batch(tokens=jnp.asarray(raw["tokens"]),
                          labels=jnp.asarray(raw["labels"]))
            state, m = step(state, batch)
            losses.append(float(m["loss"]))
    assert losses[-1] < losses[0], losses


def test_scaling_efficiency_single_node_topo_not_trivially_one():
    layers = [sim.SimLayer("l", fwd_time=1e-3, bwd_time=2e-3,
                           wgrad_bytes=100e6)]
    eff = sim.scaling_efficiency(layers, 1, hw.ETH_10G, topo=hw.CLOUD_10G,
                                 comm_algo="hier")
    # one node of local_size=4 ranks still pays intra-node communication
    assert eff < 1.0
    assert sim.scaling_efficiency(layers, 1, hw.ETH_10G) == 1.0


def test_trainer_hier_requires_factored_mesh(mesh11):
    from repro.configs import registry
    from repro.core.planner import Planner
    from repro.models.transformer import Model
    from repro.optim import optimizers as opt_lib
    from repro.train import trainer as tr

    cfg = registry.get_smoke_config("yi-6b")
    with pytest.raises(AssertionError, match="node"):
        tr.make_train_step(Model(cfg), opt_lib.adamw(1e-3), mesh11,
                           Planner(mesh=mesh11),
                           tr.CommConfig(mode="mlsl", hier=True))


# --------------------------------------------------------------------------
# compat shim unit tests (both API spellings of the call sites)
# --------------------------------------------------------------------------

def test_compat_make_mesh_accepts_both_spellings():
    m1 = compat.make_mesh((1, 1), ("a", "b"))
    m2 = compat.make_mesh((1, 1), ("a", "b"),
                          axis_types=(compat.AxisType.Auto,) * 2)
    assert m1.axis_names == m2.axis_names == ("a", "b")
    assert dict(m1.shape) == dict(m2.shape) == {"a": 1, "b": 1}


def test_compat_abstract_mesh_shape_and_names():
    am = compat.abstract_mesh((16, 16), ("data", "model"))
    assert dict(am.shape) == {"data": 16, "model": 16}
    assert tuple(am.axis_names) == ("data", "model")


def test_compat_axis_type_members():
    # call sites only ever pass .Auto today; all three members must exist
    for member in ("Auto", "Explicit", "Manual"):
        assert hasattr(compat.AxisType, member)


def test_compat_shard_map_fully_manual_default(mesh8):
    x = jnp.arange(8.0)
    y = jax.jit(compat.shard_map(
        lambda u: lax.psum(u, (hier.NODE_AXIS, hier.LOCAL_AXIS)),
        mesh=mesh8, in_specs=DSPEC, out_specs=P()))(x)
    np.testing.assert_allclose(np.asarray(y), [28.0])


def test_compat_shard_map_partial_manual_auto_complement():
    """axis_names translates to the legacy `auto` complement set: the model
    axis stays GSPMD while node/local are manual."""
    mesh = compat.make_mesh((2, 2, 2), ("node", "local", "model"))
    x = jnp.arange(8.0)
    y = jax.jit(compat.shard_map(
        lambda u: lax.psum(u, ("node", "local")),
        mesh=mesh, in_specs=P(("node", "local")), out_specs=P(),
        axis_names={"node", "local"}, check_vma=False))(x)
    np.testing.assert_allclose(np.asarray(y), [12.0, 16.0])


def test_compat_axis_size_in_manual_region(mesh8):
    sizes = jax.jit(compat.shard_map(
        lambda: (jnp.asarray(compat.axis_size(hier.NODE_AXIS), jnp.int32),
                 jnp.asarray(compat.axis_size((hier.NODE_AXIS,
                                               hier.LOCAL_AXIS)), jnp.int32)),
        mesh=mesh8, in_specs=(), out_specs=(P(), P())))()
    assert int(sizes[0]) == 2 and int(sizes[1]) == 8


def test_compat_set_mesh_is_context_manager(mesh11):
    with compat.set_mesh(mesh11):
        pass


def test_compat_version_parsing():
    assert compat._parse_version("0.4.37") == (0, 4, 37)
    assert compat._parse_version("0.5.0.dev20250101") == (0, 5, 0)
    assert compat.JAX_VERSION >= compat.MIN_SUPPORTED
