"""Chunked (online-softmax) attention equals dense attention at the model
level, across mixers and masking modes (the §Perf B1 optimization)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis "
    "(pip install -r requirements-dev.txt)")
import hypothesis.strategies as st  # noqa: E402
from hypothesis import given, settings  # noqa: E402

from repro.configs import registry
from repro.models import attention as A
from repro.models import common
from repro.models.transformer import Batch, Model


@pytest.mark.parametrize("arch", ["yi-6b", "minicpm3-4b", "chatglm3-6b",
                                  "llava-next-mistral-7b", "whisper-small"])
def test_model_forward_chunked_equals_dense(arch):
    cfg = registry.get_smoke_config(arch)
    m = Model(cfg)
    key = jax.random.PRNGKey(11)
    params = m.init(key)
    kw = {}
    if cfg.vlm_img_tokens:
        kw["img_embeds"] = jax.random.normal(
            key, (2, cfg.vlm_img_tokens, cfg.vlm_d_vision))
    if cfg.encoder is not None:
        kw["frame_embeds"] = jax.random.normal(
            key, (2, cfg.encoder.n_frames, cfg.encoder.d_input))
    tokens = jax.random.randint(key, (2, 40), 0, cfg.vocab)
    batch = Batch(tokens=tokens, **kw)
    dense = m.forward(params, batch)
    chunked = m.forward(params, batch, kv_chunk=16)
    rel = float(jnp.max(jnp.abs(dense - chunked))) / (
        float(jnp.max(jnp.abs(dense))) + 1e-9)
    assert rel < 1e-3, (arch, rel)


@settings(max_examples=15, deadline=None)
@given(st.integers(2, 97), st.integers(1, 64), st.sampled_from([None, 8, 33]))
def test_chunked_sdpa_property(seq, chunk, window):
    key = jax.random.PRNGKey(seq * 131 + chunk)
    B, H, D = 1, 2, 8
    q = jax.random.normal(key, (B, seq, H, D))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, seq, H, D))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, seq, H, D))
    mask = common.causal_mask(seq, seq, window=window)
    ref = A._sdpa(q, k, v, mask)
    out = A.chunked_sdpa(q, k, v, causal=True, window=window, kv_chunk=chunk)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-4,
                               atol=2e-5)
