"""Invariants of the discrete-event communication-scheduling simulator."""

import pytest

from repro.configs import cnn_tables
from repro.core import hw, simulator as sim


def _layers(topo="resnet50", bs=32):
    return sim.layers_from_specs(cnn_tables.TOPOLOGIES[topo](), bs,
                                 hw.XEON_6148)


@pytest.mark.parametrize("topo", sorted(cnn_tables.TOPOLOGIES))
def test_policy_ordering(topo):
    """priority exposure <= fifo exposure <= blocking exposure."""
    layers = _layers(topo)
    for p in (8, 64):
        e = {pol: sim.simulate_iteration(layers, p, hw.ETH_10G, pol,
                                         overlap_eff=0.7).exposed_comm
             for pol in sim.Policy}
        assert -1e-9 <= e[sim.Policy.PRIORITY_OVERLAP] \
            <= e[sim.Policy.FIFO_OVERLAP] + 1e-9
        assert e[sim.Policy.FIFO_OVERLAP] <= e[sim.Policy.BLOCKING] + 1e-9


def test_priority_serves_first_layer_first():
    layers = _layers()
    st = sim.simulate_iteration(layers, 64, hw.ETH_10G,
                                sim.Policy.PRIORITY_OVERLAP)
    done = st.completion_times
    # the first layer's reduction must not finish after bulk later layers
    assert done[0] <= max(done) + 1e-12
    assert done[0] <= sorted(done)[len(done) // 2]


def test_single_node_no_comm():
    layers = _layers()
    st = sim.simulate_iteration(layers, 1, hw.ETH_10G,
                                sim.Policy.FIFO_OVERLAP)
    assert st.exposed_comm == pytest.approx(0.0, abs=1e-12)
    assert st.comm_busy == pytest.approx(0.0, abs=1e-12)


def test_total_time_accounting():
    layers = _layers()
    for pol in sim.Policy:
        st = sim.simulate_iteration(layers, 32, hw.ETH_10G, pol)
        assert st.total_time >= st.compute_time - 1e-12
        assert st.exposed_comm == pytest.approx(
            st.total_time - st.compute_time)


def test_faster_link_not_worse():
    layers = _layers()
    slow = sim.simulate_iteration(layers, 64, hw.ETH_10G,
                                  sim.Policy.PRIORITY_OVERLAP)
    fast = sim.simulate_iteration(layers, 64, hw.OMNIPATH,
                                  sim.Policy.PRIORITY_OVERLAP)
    assert fast.exposed_comm <= slow.exposed_comm + 1e-12


def test_scaling_efficiency_bounds():
    layers = _layers()
    for p in (2, 16, 128):
        eff = sim.scaling_efficiency(layers, p, hw.OMNIPATH)
        assert 0.0 < eff <= 1.0
