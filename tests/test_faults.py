"""Fault-injected degradation: monotonicity of the simulator under injected
faults, and bucket routing under a degraded cost model (core/hw.py
LinkDegradation/Topology.degrade, core/simulator.py FaultSpec,
planner.choose_allreduce_algo / scheduler.route_buckets)."""

import numpy as np
import pytest

from repro.configs import cnn_tables
from repro.core import hw, planner, scheduler, simulator as sim

FAULTS = {
    "inter_bw": sim.FaultSpec(inter_bw_factor=0.5),
    "inter_latency": sim.FaultSpec(inter_latency_factor=3.0),
    "intra_bw": sim.FaultSpec(intra_bw_factor=0.3),
    "straggler": sim.FaultSpec(straggler_slowdown=1.5, straggler_node=3),
    "hetero": sim.FaultSpec(hetero_link_bw_factors=(1.0, 0.5, 0.9)),
    "compound": sim.FaultSpec(inter_bw_factor=0.7, straggler_slowdown=1.2,
                              intra_latency_factor=2.0),
}


def _layers(bs=64):
    return sim.layers_from_specs(cnn_tables.resnet50_layers(), bs,
                                 hw.XEON_6148)


# --------------------------------------------------------------------------
# hw: LinkDegradation / Topology.degrade
# --------------------------------------------------------------------------

def test_link_degradation_apply():
    deg = hw.LinkDegradation(bw_factor=0.5, latency_factor=2.0)
    link = deg.apply(hw.ETH_10G)
    assert link.bw == pytest.approx(hw.ETH_10G.bw * 0.5)
    assert link.latency == pytest.approx(hw.ETH_10G.latency * 2.0)
    assert link.name.endswith("!deg")
    assert hw.HEALTHY.healthy
    assert hw.HEALTHY.apply(hw.ETH_10G) is hw.ETH_10G


def test_link_degradation_never_improves():
    # factors >1 bw / <1 latency must not make the link FASTER
    deg = hw.LinkDegradation(bw_factor=1.5, latency_factor=0.5)
    link = deg.apply(hw.ETH_10G)
    assert link.bw <= hw.ETH_10G.bw
    assert link.latency >= hw.ETH_10G.latency


def test_topology_degrade_composes():
    t1 = hw.CLOUD_10G.degrade(inter_bw=0.5, straggler=1.5)
    t2 = t1.degrade(inter_bw=0.8, straggler=1.2)
    assert t2.effective_inter.bw == pytest.approx(hw.CLOUD_10G.inter.bw
                                                  * 0.5 * 0.8)
    assert t2.straggler == pytest.approx(1.5 * 1.2)
    # healthy topology is untouched (frozen dataclass, new instances only)
    assert hw.CLOUD_10G.straggler == 1.0
    assert hw.CLOUD_10G.effective_inter is hw.CLOUD_10G.inter


def test_degraded_allreduce_times_monotone():
    nbytes = 25e6
    for topo in hw.TOPOLOGIES.values():
        for algo_time in (hw.flat_allreduce_time, hw.hier_allreduce_time):
            t0 = algo_time(nbytes, 16, topo)
            t1 = algo_time(nbytes, 16, topo.degrade(inter_bw=0.5))
            t2 = algo_time(nbytes, 16,
                           topo.degrade(inter_bw=0.5, intra_bw=0.5,
                                        inter_latency=2.0))
            assert t0 <= t1 + 1e-12 <= t2 + 1e-9


# --------------------------------------------------------------------------
# simulator: FaultSpec monotonicity
# --------------------------------------------------------------------------

def test_fault_spec_worst_link():
    f = sim.FaultSpec(inter_bw_factor=0.8,
                      hetero_link_bw_factors=(1.0, 0.6, 0.9))
    assert f.worst_inter_bw_factor == pytest.approx(0.6)
    link = f.apply_to_link(hw.ETH_10G)
    assert link.bw == pytest.approx(hw.ETH_10G.bw * 0.6)


@pytest.mark.parametrize("policy", list(sim.Policy))
@pytest.mark.parametrize("name", sorted(FAULTS))
def test_fault_never_speeds_up_iteration(policy, name):
    """Degrading any link or adding a straggler never decreases exposed
    comm or total time -- on the bare-link path and the topology path."""
    layers = _layers()
    fault = FAULTS[name]
    for topo in (None, hw.CLOUD_10G):
        healthy = sim.simulate_iteration(layers, 64, hw.ETH_10G, policy,
                                         topo=topo)
        faulty = sim.simulate_iteration(layers, 64, hw.ETH_10G, policy,
                                        topo=topo, fault=fault)
        assert faulty.total_time >= healthy.total_time - 1e-9
        assert faulty.exposed_comm >= healthy.exposed_comm - 1e-9
        # straggler waits are exposed, not counted as useful compute
        assert faulty.compute_time == pytest.approx(healthy.compute_time)


def test_straggler_degrades_scaling_efficiency():
    layers = _layers()
    eff0 = sim.scaling_efficiency(layers, 64, hw.ETH_10G, overlap_eff=0.7)
    eff = sim.scaling_efficiency(layers, 64, hw.ETH_10G, overlap_eff=0.7,
                                 fault=sim.FaultSpec(straggler_slowdown=1.5))
    assert eff < eff0
    # a 1.5x straggler bounds efficiency by 1/1.5 even with free comm
    assert eff <= 1 / 1.5 + 1e-6


def test_exposed_comm_reduction_honors_fault():
    layers = _layers()
    r0 = sim.exposed_comm_reduction(layers, 64, hw.ETH_10G,
                                    overlap_eff=0.7, topo=hw.CLOUD_10G)
    r1 = sim.exposed_comm_reduction(
        layers, 64, hw.ETH_10G, overlap_eff=0.7, topo=hw.CLOUD_10G,
        fault=sim.FaultSpec(inter_bw_factor=0.5))
    assert r0 >= 1.0 - 1e-9 and r1 >= 1.0 - 1e-9  # prioritization never hurts


# --------------------------------------------------------------------------
# routing under degradation
# --------------------------------------------------------------------------

def test_routing_flips_flat_to_hier_on_degraded_inter():
    """CLOUD_VIRT (virtio intra slower than SR-IOV inter): bulk buckets route
    FLAT healthy; degrading the inter fabric pushes them back to HIER."""
    fault = sim.FaultSpec(inter_bw_factor=0.4)
    flipped = []
    for mb in (16.0, 25.0, 64.0):
        healthy = planner.choose_allreduce_algo(mb * 1e6, 16, hw.CLOUD_VIRT)
        degraded = planner.choose_allreduce_algo(mb * 1e6, 16, hw.CLOUD_VIRT,
                                                 fault=fault)
        flipped.append((healthy, degraded))
    assert all(h == planner.ALGO_FLAT for h, _ in flipped)
    assert all(d == planner.ALGO_HIER for _, d in flipped)


@pytest.mark.parametrize("name", sorted(FAULTS))
def test_routing_never_picks_dominated_algo(name):
    """Under any injected fault the chosen algorithm's cost on the DEGRADED
    topology is <= the alternative's -- routing is never strictly
    dominated."""
    fault = FAULTS[name]
    for topo in hw.TOPOLOGIES.values():
        for nbytes in (4e3, 1e6, 25e6, 1e8):
            algo = planner.choose_allreduce_algo(nbytes, 16, topo,
                                                 fault=fault)
            deg = fault.apply_to_topology(topo)
            t_flat = hw.flat_allreduce_time(nbytes, 16, deg)
            t_hier = hw.hier_allreduce_time(nbytes, 16, deg)
            chosen = t_flat if algo == planner.ALGO_FLAT else t_hier
            assert chosen <= min(t_flat, t_hier) + 1e-12, \
                f"{topo.name} nbytes={nbytes:g}: {algo} dominated"


def test_route_buckets_accepts_fault():
    sizes = [int(mb * 1e6 / 4) for mb in (0.25, 16.0, 25.0, 64.0)]
    tree = {f"l{i}": np.broadcast_to(np.float32(0), (n,))
            for i, n in enumerate(sizes)}
    plan = scheduler.plan_buckets(tree, bucket_bytes=1.0)  # 1 leaf/bucket
    healthy = scheduler.route_buckets(plan, hw.CLOUD_VIRT, 16)
    degraded = scheduler.route_buckets(
        plan, hw.CLOUD_VIRT, 16, fault=sim.FaultSpec(inter_bw_factor=0.4))
    assert len(healthy) == len(degraded) == len(sizes)
    assert healthy != degraded          # the degraded fabric re-routes
    assert all(a in (planner.ALGO_FLAT, planner.ALGO_HIER)
               for a in list(healthy) + list(degraded))


def test_healthy_fault_is_identity():
    layers = _layers()
    for policy in sim.Policy:
        a = sim.simulate_iteration(layers, 64, hw.ETH_10G, policy,
                                   topo=hw.CLOUD_10G)
        b = sim.simulate_iteration(layers, 64, hw.ETH_10G, policy,
                                   topo=hw.CLOUD_10G, fault=sim.HEALTHY_FAULT)
        assert a.total_time == pytest.approx(b.total_time)
        assert a.exposed_comm == pytest.approx(b.exposed_comm)
    assert planner.choose_allreduce_algo(25e6, 16, hw.CLOUD_VIRT,
                                         fault=sim.HEALTHY_FAULT) \
        == planner.choose_allreduce_algo(25e6, 16, hw.CLOUD_VIRT)
