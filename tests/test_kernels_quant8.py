"""Per-kernel validation: Pallas (interpret mode) vs the pure-jnp oracle,
swept over shapes and dtypes."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, quant8, ref

SHAPES = [(8, 512), (16, 128), (64, 640), (8, 1024)]
DTYPES = [jnp.float32, jnp.bfloat16]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_quantize_blocks_matches_ref(shape, dtype):
    x = (jax.random.normal(jax.random.PRNGKey(42), shape) * 3).astype(dtype)
    x2 = x.astype(jnp.float32)
    q_p, s_p = quant8.quantize_blocks(x2, interpret=True)
    q_r, s_r = ref.quantize_blocks(x2)
    # interpret-mode XLA may fuse the divide differently; allow 1-LSB
    # rounding-tie differences on a tiny fraction of elements
    diff = np.abs(np.asarray(q_p, np.int32) - np.asarray(q_r, np.int32))
    assert diff.max() <= 1, diff.max()
    assert (diff > 0).mean() < 0.01
    np.testing.assert_allclose(np.asarray(s_p), np.asarray(s_r), rtol=1e-6)


@pytest.mark.parametrize("shape", SHAPES)
def test_dequantize_blocks_matches_ref(shape):
    x = jax.random.normal(jax.random.PRNGKey(1), shape)
    q, s = ref.quantize_blocks(x)
    d_p = quant8.dequantize_blocks(q, s, interpret=True)
    d_r = ref.dequantize_blocks(q, s)
    np.testing.assert_allclose(np.asarray(d_p), np.asarray(d_r), rtol=1e-6)


@pytest.mark.parametrize("shape", SHAPES[:2])
def test_dequant_accumulate_matches_ref(shape):
    x = jax.random.normal(jax.random.PRNGKey(2), shape)
    acc = jax.random.normal(jax.random.PRNGKey(3), shape)
    q, s = ref.quantize_blocks(x)
    a_p = quant8.dequantize_accumulate_blocks(q, s, acc, interpret=True)
    a_r = ref.dequantize_accumulate_blocks(q, s, acc)
    np.testing.assert_allclose(np.asarray(a_p), np.asarray(a_r), rtol=1e-4,
                               atol=1e-5)


@pytest.mark.parametrize("n", [1, 100, 511, 512, 4097, 70000])
@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_ops_roundtrip_arbitrary_sizes(n, backend):
    x = jax.random.normal(jax.random.PRNGKey(n), (n,)) * 0.01
    q, s, meta = ops.quantize(x, backend=backend)
    xr = ops.dequantize(q, s, meta, backend=backend)
    assert xr.shape == x.shape
    # per-block error bound: |x - xr| <= scale/2 <= amax/(2*127)
    amax = float(jnp.max(jnp.abs(x)))
    assert float(jnp.max(jnp.abs(x - xr))) <= amax / 127.0 + 1e-8


def test_roundtrip_zeros_and_extremes():
    for backend in ("jnp", "pallas"):
        z = jnp.zeros((1000,))
        q, s, meta = ops.quantize(z, backend=backend)
        assert float(jnp.max(jnp.abs(ops.dequantize(q, s, meta,
                                                    backend=backend)))) == 0.0
        big = jnp.full((1000,), 1e20)
        q, s, meta = ops.quantize(big, backend=backend)
        np.testing.assert_allclose(
            np.asarray(ops.dequantize(q, s, meta, backend=backend)),
            np.asarray(big), rtol=1e-2)
