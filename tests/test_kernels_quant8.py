"""Per-kernel validation: Pallas (interpret mode) vs the pure-jnp oracle,
swept over shapes and dtypes, plus the fused-vs-composed contracts of the
single-pass error-feedback hot path (quantize_ef / dequantize_accumulate)."""

import inspect

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from jax.sharding import PartitionSpec as P

from repro import compat
from repro.kernels import ops, quant8, ref

SHAPES = [(8, 512), (16, 128), (64, 640), (8, 1024)]
DTYPES = [jnp.float32, jnp.bfloat16]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_quantize_blocks_matches_ref(shape, dtype):
    x = (jax.random.normal(jax.random.PRNGKey(42), shape) * 3).astype(dtype)
    x2 = x.astype(jnp.float32)
    q_p, s_p = quant8.quantize_blocks(x2, interpret=True)
    q_r, s_r = ref.quantize_blocks(x2)
    # interpret-mode XLA may fuse the divide differently; allow 1-LSB
    # rounding-tie differences on a tiny fraction of elements
    diff = np.abs(np.asarray(q_p, np.int32) - np.asarray(q_r, np.int32))
    assert diff.max() <= 1, diff.max()
    assert (diff > 0).mean() < 0.01
    np.testing.assert_allclose(np.asarray(s_p), np.asarray(s_r), rtol=1e-6)


@pytest.mark.parametrize("shape", SHAPES)
def test_dequantize_blocks_matches_ref(shape):
    x = jax.random.normal(jax.random.PRNGKey(1), shape)
    q, s = ref.quantize_blocks(x)
    d_p = quant8.dequantize_blocks(q, s, interpret=True)
    d_r = ref.dequantize_blocks(q, s)
    np.testing.assert_allclose(np.asarray(d_p), np.asarray(d_r), rtol=1e-6)


@pytest.mark.parametrize("shape", SHAPES[:2])
def test_dequant_accumulate_matches_ref(shape):
    x = jax.random.normal(jax.random.PRNGKey(2), shape)
    acc = jax.random.normal(jax.random.PRNGKey(3), shape)
    q, s = ref.quantize_blocks(x)
    a_p = quant8.dequantize_accumulate_blocks(q, s, acc, interpret=True)
    a_r = ref.dequantize_accumulate_blocks(q, s, acc)
    np.testing.assert_allclose(np.asarray(a_p), np.asarray(a_r), rtol=1e-4,
                               atol=1e-5)


@pytest.mark.parametrize("n", [1, 100, 511, 512, 4097, 70000])
@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_ops_roundtrip_arbitrary_sizes(n, backend):
    x = jax.random.normal(jax.random.PRNGKey(n), (n,)) * 0.01
    q, s, meta = ops.quantize(x, backend=backend)
    xr = ops.dequantize(q, s, meta, backend=backend)
    assert xr.shape == x.shape
    # per-block error bound: |x - xr| <= scale/2 <= amax/(2*127)
    amax = float(jnp.max(jnp.abs(x)))
    assert float(jnp.max(jnp.abs(x - xr))) <= amax / 127.0 + 1e-8


def test_roundtrip_zeros_and_extremes():
    for backend in ("jnp", "pallas"):
        z = jnp.zeros((1000,))
        q, s, meta = ops.quantize(z, backend=backend)
        assert float(jnp.max(jnp.abs(ops.dequantize(q, s, meta,
                                                    backend=backend)))) == 0.0
        big = jnp.full((1000,), 1e20)
        q, s, meta = ops.quantize(big, backend=backend)
        np.testing.assert_allclose(
            np.asarray(ops.dequantize(q, s, meta, backend=backend)),
            np.asarray(big), rtol=1e-2)


# ---------------------------------------------------------------------------
# Fused single-pass EF hot path: quantize_ef vs the composed three-pass data
# path (cast+add, quantize, dequantize_accumulate residual update)
# ---------------------------------------------------------------------------

def _compose_ef(x2d, res2d):
    """The unfused reference decomposition of quantize_ef_blocks."""
    y = x2d.astype(jnp.float32) + res2d.astype(jnp.float32)
    q, s = ref.quantize_blocks(y)
    new_res = ref.dequantize_accumulate_blocks(q, -s, y)
    return q, s, new_res


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_quantize_ef_blocks_jnp_bitwise_vs_composed(shape, dtype):
    """The fused jnp oracle and the hand-composed passes run the identical
    expression graph eagerly, so they must agree BITWISE — fp32 and bf16
    wire dtypes alike (the cast is exact, the negated-scale residual
    update is an IEEE sign flip)."""
    x = (jax.random.normal(jax.random.PRNGKey(7), shape) * 3).astype(dtype)
    res = jax.random.normal(jax.random.PRNGKey(8), shape) * 0.01
    q_f, s_f, r_f = ref.quantize_ef_blocks(x, res)
    q_c, s_c, r_c = _compose_ef(x, res)
    np.testing.assert_array_equal(np.asarray(q_f), np.asarray(q_c))
    np.testing.assert_array_equal(np.asarray(s_f), np.asarray(s_c))
    np.testing.assert_array_equal(np.asarray(r_f), np.asarray(r_c))


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_quantize_ef_blocks_pallas_vs_composed(shape, dtype):
    """Pallas (interpret) vs the composed oracle: interpret-mode XLA may
    fuse the divide differently, so q gets the same 1-LSB rounding-tie
    policy as plain quantize; the residual error is then bounded by the
    per-row scale for flipped elements (plus float slack elsewhere)."""
    x = (jax.random.normal(jax.random.PRNGKey(9), shape) * 3).astype(dtype)
    res = jax.random.normal(jax.random.PRNGKey(10), shape) * 0.01
    q_p, s_p, r_p = quant8.quantize_ef_blocks(x, res, interpret=True)
    q_c, s_c, r_c = _compose_ef(x, res)
    qdiff = np.abs(np.asarray(q_p, np.int32) - np.asarray(q_c, np.int32))
    assert qdiff.max() <= 1, qdiff.max()
    assert (qdiff > 0).mean() < 0.01
    np.testing.assert_allclose(np.asarray(s_p), np.asarray(s_c), rtol=1e-6)
    # |r_p - r_c| <= scale where q flipped by 1 LSB, ~0 elsewhere
    bound = np.asarray(s_c)[:, None] * (qdiff + 1e-3) + 1e-7
    assert (np.abs(np.asarray(r_p) - np.asarray(r_c)) <= bound).all()


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_quantize_cast_blocks_folds_wire_cast(backend):
    """quantize(bf16 buffer) == quantize(f32 copy of it): the wire cast is
    inside the tile/oracle, so no separate cast pass is ever needed."""
    x16 = (jax.random.normal(jax.random.PRNGKey(11), (3000,)) * 2
           ).astype(jnp.bfloat16)
    q_a, s_a, _ = ops.quantize(x16, backend=backend)
    q_b, s_b, _ = ops.quantize(x16.astype(jnp.float32), backend=backend)
    np.testing.assert_array_equal(np.asarray(q_a), np.asarray(q_b))
    np.testing.assert_array_equal(np.asarray(s_a), np.asarray(s_b))


@pytest.mark.parametrize("n", [1, 100, 511, 4097, 70000])
@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_ops_quantize_ef_odd_sizes(n, backend):
    """Shape-polymorphic fused EF: padding round-trips and the residual
    comes back in the caller's (odd) shape with the invariant
    y = dequant(q) + new_residual holding per element."""
    x = jax.random.normal(jax.random.PRNGKey(n), (n,)).astype(jnp.bfloat16)
    res = jax.random.normal(jax.random.PRNGKey(n + 1), (n,)) * 0.01
    q, s, meta, new_res = ops.quantize_ef(x, res, backend=backend)
    assert new_res.shape == (n,) and new_res.dtype == jnp.float32
    y = x.astype(jnp.float32) + res
    deq = ops.dequantize(q, s,
                         ops.QuantMeta(shape=(n,), dtype=jnp.float32, n=n,
                                       block=meta.block), backend=backend)
    np.testing.assert_allclose(np.asarray(deq + new_res), np.asarray(y),
                               rtol=1e-5, atol=1e-7)


def test_quantize_ef_rejects_mismatched_residual():
    x = jnp.zeros((100,))
    with pytest.raises(ValueError, match="residual"):
        ops.quantize_ef(x, jnp.zeros((99,)))


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_quantize_ef_all_zero_blocks(backend):
    """amax == 0 rows: scale 0, q 0, and the residual carries y through
    unchanged (the safe-divide guard, same policy as plain quantize)."""
    x = jnp.zeros((8, 512))
    res = jnp.zeros((8, 512))
    if backend == "pallas":
        q, s, r = quant8.quantize_ef_blocks(x, res, interpret=True)
    else:
        q, s, r = ref.quantize_ef_blocks(x, res)
    assert not np.asarray(q).any()
    assert not np.asarray(s).any()
    assert not np.asarray(r).any()


def test_quantize_ef_inf_amax_rows_agree_across_backends():
    """A row containing inf drives amax (and the scale) to inf; whatever
    the resulting q/residual policy is, both backends must agree on it.
    q and scales are exact; the residual allows FMA-contraction slack on
    the finite rows (interpret-mode XLA fuses y - q*s) and compares the
    inf row's nans as equal (assert_allclose is nan-aware)."""
    x = jnp.ones((8, 512)).at[0, 3].set(jnp.inf)
    res = jnp.zeros((8, 512))
    q_p, s_p, r_p = quant8.quantize_ef_blocks(x, res, interpret=True)
    q_r, s_r, r_r = ref.quantize_ef_blocks(x, res)
    np.testing.assert_array_equal(np.asarray(q_p), np.asarray(q_r))
    np.testing.assert_array_equal(np.asarray(s_p), np.asarray(s_r))
    assert np.isnan(np.asarray(r_p)[0]).all()       # the inf row
    np.testing.assert_allclose(np.asarray(r_p), np.asarray(r_r), atol=1e-7)


def test_dequantize_accumulate_keeps_acc_dtype():
    """Accumulating into an f32 buffer stays f32 even when the quantized
    tensor was a bf16 wire buffer (meta.dtype must not leak in)."""
    x = jax.random.normal(jax.random.PRNGKey(12), (700,)).astype(jnp.bfloat16)
    q, s, meta = ops.quantize(x)
    acc = jax.random.normal(jax.random.PRNGKey(13), (700,))
    out = ops.dequantize_accumulate(q, s, acc, meta)
    assert out.dtype == jnp.float32


# ---------------------------------------------------------------------------
# Shape-contract errors + pad-waste accounting
# ---------------------------------------------------------------------------

def test_grid_rejects_ragged_rows_with_shape():
    with pytest.raises(ValueError) as ei:
        quant8.quantize_blocks(jnp.zeros((3, 512)), interpret=True)
    assert "3" in str(ei.value) and "TILE_ROWS" in str(ei.value)


def test_block_rejects_non_lane_multiple_with_shape():
    with pytest.raises(ValueError) as ei:
        quant8.quantize_blocks(jnp.zeros((8, 100)), interpret=True)
    assert "100" in str(ei.value) and "128" in str(ei.value)


def test_pad_info_reports_tiny_bucket_waste():
    quantum = quant8.TILE_ROWS * quant8.DEFAULT_BLOCK
    info = ops.pad_info(100)
    assert info.padded == quantum
    assert info.waste_elems == quantum - 100
    assert info.waste_frac == pytest.approx((quantum - 100) / quantum)
    assert ops.pad_info(quantum).waste_frac == 0.0


def test_backend_policy_is_single_sourced():
    """No comm call site hardcodes the kernel backend: core/collectives.py
    resolves it via the kernels/ops.py policy only."""
    import repro.core.collectives as cl
    src = inspect.getsource(cl)
    assert 'backend="jnp"' not in src and "backend='jnp'" not in src
    assert 'backend="pallas"' not in src and "backend='pallas'" not in src
    with pytest.raises(ValueError, match="unknown quantization backend"):
        ops.wire_backend("cuda")
    assert ops.wire_backend("pallas") == "pallas"
    assert ops.wire_backend("jnp") == "jnp"
    assert ops.wire_backend() in ("pallas", "jnp")


# ---------------------------------------------------------------------------
# End-to-end on the factored mesh: the fused wire is a drop-in
# ---------------------------------------------------------------------------

def test_allreduce_ef_fused_matches_unfused_mesh8(mesh8):
    """Fused and composed EF data paths produce bitwise-identical reduced
    gradients AND residuals through a real 8-rank exchange."""
    from repro.core import collectives as cl

    ax = ("node", "local")
    p, n = 8, 70000
    x = jax.random.normal(jax.random.PRNGKey(21), (n,))
    res = jax.random.normal(jax.random.PRNGKey(22),
                            (cl.ef_residual_shape(n, p)[0] * p,)) * 0.01

    def run(fused):
        def f(xs, rs):
            return cl.allreduce_ef(xs, rs, ax, mean=True, backend="jnp",
                                   fused=fused)
        w = compat.shard_map(f, mesh=mesh8, in_specs=(P(), P(ax)),
                             out_specs=(P(), P(ax)), axis_names=set(ax),
                             check_vma=False)
        return w(x, res)

    o_f, r_f = run(True)
    o_u, r_u = run(False)
    np.testing.assert_array_equal(np.asarray(o_f), np.asarray(o_u))
    np.testing.assert_array_equal(np.asarray(r_f), np.asarray(r_u))


def test_hier_allreduce_ef_fused_matches_unfused_mesh8(mesh8):
    """Same drop-in contract through the two-level path (the fabric leg is
    where the fused kernels actually run in production plans)."""
    from repro.core import collectives as cl
    from repro.core import hier as hier_lib

    ax = ("node", "local")
    n = 70000
    x = jax.random.normal(jax.random.PRNGKey(23), (n,))
    res = jax.random.normal(
        jax.random.PRNGKey(24),
        (hier_lib.ef_residual_shape(n, 4, 2)[0] * 8,)) * 0.01

    def run(fused):
        spec = hier_lib.HierSpec(wire_inter=cl.WIRE_INT8,
                                 error_feedback=True, backend="jnp",
                                 fused=fused)

        def f(xs, rs):
            return hier_lib.hier_allreduce_ef(xs, rs, spec, mean=True)
        w = compat.shard_map(f, mesh=mesh8, in_specs=(P(), P(ax)),
                             out_specs=(P(), P(ax)), axis_names=set(ax),
                             check_vma=False)
        return w(x, res)

    o_f, r_f = run(True)
    o_u, r_u = run(False)
    np.testing.assert_array_equal(np.asarray(o_f), np.asarray(o_u))
    np.testing.assert_array_equal(np.asarray(r_f), np.asarray(r_u))


def test_allreduce_int8_acc_folds_accumulate_mesh8(mesh8):
    """The gather-side `acc` path (dequantize_accumulate) equals reducing
    then adding — bitwise, since q * s + acc is evaluated identically."""
    from repro.core import collectives as cl

    ax = ("node", "local")
    n = 5000
    x = jax.random.normal(jax.random.PRNGKey(25), (n,))
    acc = jax.random.normal(jax.random.PRNGKey(26), (n,))

    def run(use_acc):
        def f(xs, accs):
            return cl.allreduce(xs, ax, wire=cl.WIRE_INT8, mean=True,
                                backend="jnp",
                                acc=accs if use_acc else None)
        w = compat.shard_map(f, mesh=mesh8, in_specs=(P(), P()),
                             out_specs=P(), axis_names=set(ax),
                             check_vma=False)
        return w(x, acc)

    fused_out = run(True)
    plain = run(False)
    np.testing.assert_array_equal(np.asarray(fused_out),
                                  np.asarray(acc) + np.asarray(plain))


# ---------------------------------------------------------------------------
# Property tests (hypothesis): the fused kernel is total over its domain
# ---------------------------------------------------------------------------

try:
    import hypothesis
    import hypothesis.strategies as st
    from hypothesis import given, settings
except ImportError:                                     # pragma: no cover
    hypothesis = None

needs_hypothesis = pytest.mark.skipif(
    hypothesis is None, reason="property tests need hypothesis")


@needs_hypothesis
@settings(max_examples=30, deadline=None) if hypothesis else (lambda f: f)
@given(n=st.integers(min_value=1, max_value=9000),
       seed=st.integers(min_value=0, max_value=2**31 - 1),
       scale_exp=st.integers(min_value=-20, max_value=20)) \
    if hypothesis else (lambda f: f)
def test_property_fused_ef_bitwise_vs_composed(n, seed, scale_exp):
    """For arbitrary sizes and magnitudes the fused jnp path is bitwise
    equal to composing quantize + dequantize_accumulate by hand."""
    key = jax.random.PRNGKey(seed)
    kx, kr = jax.random.split(key)
    x = (jax.random.normal(kx, (n,)) * (2.0 ** scale_exp)
         ).astype(jnp.bfloat16)
    res = jax.random.normal(kr, (n,)) * (2.0 ** (scale_exp - 7))
    q_f, s_f, meta, r_f = ops.quantize_ef(x, res, backend="jnp")
    y = x.astype(jnp.float32) + res
    q_c, s_c, meta_c = ops.quantize(y, backend="jnp")
    r_c = ops.dequantize_accumulate(q_c, -s_c, y, meta_c, backend="jnp")
    np.testing.assert_array_equal(np.asarray(q_f), np.asarray(q_c))
    np.testing.assert_array_equal(np.asarray(s_f), np.asarray(s_c))
    np.testing.assert_array_equal(np.asarray(r_f), np.asarray(r_c))
