import jax
import numpy as np

from repro.configs import registry
from repro.models.transformer import Model
from repro.serve.engine import Engine, EngineConfig, Request, serve_requests


def _engine(arch="yi-6b", **kw):
    cfg = registry.get_smoke_config(arch)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return Engine(model, params, EngineConfig(max_seq=64, **kw)), cfg


def test_generate_shapes_and_determinism():
    eng, cfg = _engine()
    prompts = np.random.default_rng(0).integers(0, cfg.vocab, (3, 10),
                                                dtype=np.int64).astype(
                                                    np.int32)
    a = eng.generate(prompts, 6)
    b = eng.generate(prompts, 6)
    assert a.shape == (3, 6)
    np.testing.assert_array_equal(a, b)          # greedy == deterministic
    assert (a >= 0).all() and (a < cfg.vocab).all()


def test_serve_requests_batched():
    eng, cfg = _engine("mamba2-2.7b")
    rng = np.random.default_rng(1)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab, size=n).astype(
        np.int32), max_new=4 + i)
        for i, n in enumerate((4, 9, 13))]
    out = serve_requests(eng, reqs)
    for i, r in enumerate(out):
        assert r.out.shape == (4 + i,)


def test_long_context_engine():
    eng, cfg = _engine(long_context=True)
    prompts = np.zeros((1, 8), np.int32)
    out = eng.generate(prompts, 4)
    assert out.shape == (1, 4)


def test_engine_int8_kv_cache():
    eng, cfg = _engine(kv_dtype="int8")
    prompts = np.random.default_rng(2).integers(0, cfg.vocab, (2, 12),
                                                dtype=np.int64).astype(
                                                    np.int32)
    out = eng.generate(prompts, 5)
    assert out.shape == (2, 5)
    # greedy decode with and without quantization should mostly agree on a
    # reduced model (logit gaps dominate the 1% quantization error)
    ref, _ = _engine()
    # note: fresh params per engine; compare only shapes/determinism here
    out2 = eng.generate(prompts, 5)
    np.testing.assert_array_equal(out, out2)
