"""Optimizers: convergence on a quadratic + state dtype handling."""

import jax
import jax.numpy as jnp
import pytest

from repro.optim import optimizers as opt_lib, schedules


@pytest.mark.parametrize("name", sorted(opt_lib.OPTIMIZERS))
def test_optimizer_reduces_quadratic(name):
    kw = {"weight_decay": 0.0} if name != "lars" else {"weight_decay": 0.0,
                                                       "trust_coeff": 0.1}
    opt = opt_lib.make_optimizer(name, 0.1, **kw)
    params = {"w": jnp.asarray([3.0, -2.0, 1.5]), "b": jnp.asarray([1.0])}
    state = opt.init(params)

    def loss(p):
        return jnp.sum(p["w"] ** 2) + jnp.sum(p["b"] ** 2)

    l0 = float(loss(params))
    for step in range(60):
        g = jax.grad(loss)(params)
        params, state = opt.update(g, state, params,
                                   jnp.asarray(step, jnp.int32))
    assert float(loss(params)) < 0.2 * l0, (name, float(loss(params)))


def test_bf16_state_dtype():
    opt = opt_lib.adamw(1e-3, state_dtype=jnp.bfloat16)
    params = {"w": jnp.ones((4, 4))}
    state = opt.init(params)
    assert state["m"]["w"].dtype == jnp.bfloat16
    g = {"w": jnp.ones((4, 4))}
    params2, state2 = opt.update(g, state, params, jnp.int32(0))
    assert params2["w"].dtype == params["w"].dtype
    assert state2["v"]["w"].dtype == jnp.bfloat16


def test_grad_clip():
    g = {"a": jnp.full((10,), 100.0)}
    clipped, gn = opt_lib.clip_by_global_norm(g, 1.0)
    assert float(gn) > 100
    assert abs(float(jnp.linalg.norm(clipped["a"])) - 1.0) < 1e-5


def test_schedules():
    s = schedules.warmup_cosine(1.0, 10, 100)
    assert float(s(jnp.int32(0))) < 0.2
    assert abs(float(s(jnp.int32(10))) - 1.0) < 0.11
    assert float(s(jnp.int32(99))) < 0.2
    assert schedules.linear_batch_scaled(0.1, 256, 8192) == pytest.approx(3.2)
