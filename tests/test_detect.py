"""Telemetry channel + online health monitor (repro.obs.telemetry/detect).

The PR-10 acceptance claims verified here:

  * the JSONL telemetry stream round-trips through write/load/validate,
    malformed streams are rejected, and the simulator's labeled episode
    generator emits schema-compliant events;
  * the detector fires the RIGHT typed alarm on each PR-6 fault scenario
    (straggler, degraded-inter, hetero links, congested intra, step drift
    with sampling off) with the estimated degradation factor within
    tolerance of the injected one;
  * ZERO false positives on clean deterministic episodes, and warm-up
    steps never alarm;
  * alarm factors map into ``Topology.degrade`` convention and the reroute
    hook reports bucket-routing changes for link faults.
"""

import pytest

from repro.core import engine as eng
from repro.core import hier, hw, planner
from repro.core import simulator as sim
from repro.obs import detect, telemetry

DATA_AXES = (hier.NODE_AXIS, hier.LOCAL_AXIS)

BUCKET_BYTES = (25e6, 25e6, 25e6, 12e6, 4e6, 1e6, 0.25e6)
VIRT = "cloud-virtio-sriov"


def _routed_algos(nodes=16, topo_name=VIRT):
    topo = hw.TOPOLOGIES[topo_name]
    return tuple(planner.choose_allreduce_algo(b, nodes, topo)
                 for b in BUCKET_BYTES)


def _replay(spec, algos=None):
    algos = algos or _routed_algos(spec.nodes, spec.topo_name)
    events = sim.generate_episode(spec, BUCKET_BYTES, algos)
    telemetry.validate_telemetry(events)
    mon = detect.HealthMonitor(bucket_bytes=BUCKET_BYTES, algos=algos,
                               nodes=spec.nodes, topo=spec.topo_name)
    mon.replay(events)
    return mon


# --------------------------------------------------------------------------
# telemetry channel
# --------------------------------------------------------------------------

def test_telemetry_round_trip(tmp_path):
    path = str(tmp_path / "telemetry.jsonl")
    with telemetry.TelemetryWriter(path, run_info={"arch": "yi-6b"},
                                   sample_every=5) as tel:
        tel.step(step=0, t_step_s=0.5, tok_s=1e4, loss=3.2,
                 exposed_frac=0.1)
        tel.bucket_times(0, [1e-3, 2e-3], modeled=[1.1e-3, 1.9e-3])
        tel.alarm(step=7, kind="straggler", factor=1.5, detail="test")
    events = telemetry.load_telemetry(path)
    kinds = [e["kind"] for e in events]
    assert kinds == ["meta", "step", "bucket_times", "alarm"]
    assert events[0]["schema_version"] == telemetry.SCHEMA_VERSION
    assert events[0]["run"]["arch"] == "yi-6b"
    assert events[1]["t_step_s"] == 0.5 and events[1]["loss"] == 3.2
    assert events[2]["measured"] == [1e-3, 2e-3]
    assert events[3]["alarm"]["kind"] == "straggler"
    assert events[3]["alarm"]["factor"] == 1.5


def test_telemetry_sampling_knob(tmp_path):
    tel = telemetry.TelemetryWriter(str(tmp_path / "t.jsonl"),
                                    sample_every=25)
    assert tel.should_sample(0) and tel.should_sample(50)
    assert not tel.should_sample(26)
    tel.close()
    off = telemetry.TelemetryWriter(str(tmp_path / "t0.jsonl"),
                                    sample_every=0)
    assert not any(off.should_sample(s) for s in range(100))
    off.close()


def test_validate_telemetry_rejects_malformed():
    meta = {"kind": "meta", "schema_version": 1, "created_unix": 0.0,
            "sample_every": 25, "run": {}}
    with pytest.raises(ValueError):
        telemetry.validate_telemetry([])                       # no meta
    with pytest.raises(ValueError):
        telemetry.validate_telemetry([{"kind": "step", "step": 0,
                                       "t_step_s": 1.0}])      # meta not 1st
    with pytest.raises(ValueError):
        telemetry.validate_telemetry([meta, meta])             # dup meta
    with pytest.raises(ValueError):
        telemetry.validate_telemetry(
            [meta, {"kind": "wat", "step": 0}])                # unknown kind
    with pytest.raises(ValueError):
        telemetry.validate_telemetry(
            [meta, {"kind": "step", "step": 0}])               # no t_step_s
    with pytest.raises(ValueError):
        telemetry.validate_telemetry(
            [meta, {"kind": "bucket_times", "step": 0}])       # no columns
    with pytest.raises(ValueError):
        telemetry.validate_telemetry(
            [meta, {"kind": "bucket_times", "step": 0,
                    "measured": [-1.0]}])                      # negative
    with pytest.raises(ValueError):
        telemetry.validate_telemetry(
            [meta, {"kind": "alarm", "step": 0,
                    "alarm": {"kind": "straggler"}}])          # no factor
    with pytest.raises(ValueError):
        telemetry.validate_telemetry(
            [{**meta, "schema_version": 99}, ])                # future ver


def test_bucket_times_requires_a_column(tmp_path):
    tel = telemetry.TelemetryWriter(str(tmp_path / "t.jsonl"))
    with pytest.raises(ValueError):
        tel.bucket_times(0)
    tel.close()


# --------------------------------------------------------------------------
# clean runs: no alarms, warm-up never alarms
# --------------------------------------------------------------------------

def test_clean_episode_zero_alarms():
    mon = _replay(sim.EpisodeSpec(name="clean", label="clean"))
    assert mon.alarms == []


def test_clean_hier_episode_zero_alarms():
    mon = _replay(sim.EpisodeSpec(name="clean_hier", label="clean", seed=1),
                  algos=tuple("hier" for _ in BUCKET_BYTES))
    assert mon.alarms == []


def test_warmup_never_alarms():
    """Even violent drift during calibration cannot fire: the first
    warmup_steps observations only build the baseline."""
    cfg = detect.DetectorConfig(warmup_steps=10)
    mon = detect.HealthMonitor(bucket_bytes=BUCKET_BYTES,
                               algos=_routed_algos(), nodes=16, topo=VIRT,
                               config=cfg)
    for s in range(cfg.warmup_steps):
        # wildly varying times while calibrating
        assert mon.observe_step(s, 1.0 + (s % 3)) == []
        assert mon.observe_bucket_times(s, [1e-3 * (s + 1)] * 7) == []
    assert mon.alarms == []
    assert not mon.in_warmup


def test_fault_from_step_zero_never_alarms():
    """A fault active from step 0 becomes the baseline — the monitor
    detects CHANGE, not absolute badness, so it must stay silent."""
    spec = sim.EpisodeSpec(name="always_slow", label="clean",
                           fault=sim.FaultSpec(straggler_slowdown=2.0),
                           onset=0, seed=9)
    mon = _replay(spec)
    assert mon.alarms == []


# --------------------------------------------------------------------------
# typed alarms on PR-6 fault scenarios
# --------------------------------------------------------------------------

@pytest.mark.parametrize("slowdown", [1.5, 2.0])
def test_straggler_detected_with_factor(slowdown):
    spec = sim.EpisodeSpec(name="straggler", label="straggler",
                           fault=sim.FaultSpec(straggler_slowdown=slowdown),
                           seed=2)
    mon = _replay(spec)
    assert len(mon.alarms) == 1
    a = mon.alarms[0]
    assert a.kind == detect.ALARM_STRAGGLER
    assert a.step >= spec.onset
    assert a.factor == pytest.approx(slowdown, rel=0.25)
    assert abs(a.factor - slowdown) < 0.15
    assert a.degrade_kwargs() == {"straggler": a.factor}


@pytest.mark.parametrize("bw_factor", [0.4, 0.6])
def test_degraded_inter_detected_with_factor(bw_factor):
    spec = sim.EpisodeSpec(name="deg_inter", label="link_degraded",
                           level="inter",
                           fault=sim.FaultSpec(inter_bw_factor=bw_factor),
                           seed=4)
    mon = _replay(spec)
    assert len(mon.alarms) == 1
    a = mon.alarms[0]
    assert a.kind == detect.ALARM_LINK_DEGRADED and a.level == "inter"
    assert a.step >= spec.onset
    assert abs(a.factor - bw_factor) <= 0.1
    assert a.degrade_kwargs() == {"inter_bw": a.factor}


def test_hetero_links_detected_as_worst_inter():
    fault = sim.FaultSpec(hetero_link_bw_factors=(1.0, 0.6, 0.9))
    spec = sim.EpisodeSpec(name="hetero", label="link_degraded",
                           level="inter", fault=fault, seed=6)
    mon = _replay(spec)
    assert len(mon.alarms) == 1
    a = mon.alarms[0]
    assert a.kind == detect.ALARM_LINK_DEGRADED and a.level == "inter"
    # the detector sees the critical path: the WORST link's factor
    assert abs(a.factor - fault.worst_inter_bw_factor) <= 0.1


def test_congested_intra_detected_on_hier_plan():
    """Intra-vs-inter discrimination: on an all-hier cloud-virtio plan the
    intra legs carry the bulk of the volume, so an intra fault's per-bucket
    drift signature cannot be mimicked by any inter hypothesis."""
    spec = sim.EpisodeSpec(name="intra", label="link_degraded",
                           level="intra",
                           fault=sim.FaultSpec(intra_bw_factor=0.25),
                           seed=7)
    mon = _replay(spec, algos=tuple("hier" for _ in BUCKET_BYTES))
    assert len(mon.alarms) == 1
    a = mon.alarms[0]
    assert a.kind == detect.ALARM_LINK_DEGRADED and a.level == "intra"
    assert abs(a.factor - 0.25) <= 0.1
    assert a.degrade_kwargs() == {"intra_bw": a.factor}


def test_step_drift_fallback_without_sampling():
    """Bucket replay disabled (sample_every=0): only the generic
    step_time_drift alarm is reachable, and it must still fire."""
    spec = sim.EpisodeSpec(name="drift", label="step_time_drift",
                           fault=sim.FaultSpec(straggler_slowdown=1.8),
                           sample_every=0, seed=8)
    mon = _replay(spec)
    assert len(mon.alarms) == 1
    a = mon.alarms[0]
    assert a.kind == detect.ALARM_STEP_DRIFT
    assert a.factor > 1.2
    assert a.degrade_kwargs() == {"straggler": a.factor}


def test_link_fault_not_misread_as_straggler():
    """A link fault also drifts step time; with bucket sampling on, the
    monitor must classify at the bucket stream and never cry straggler."""
    spec = sim.EpisodeSpec(name="deg", label="link_degraded", level="inter",
                           fault=sim.FaultSpec(inter_bw_factor=0.4), seed=4)
    mon = _replay(spec)
    assert all(a.kind != detect.ALARM_STRAGGLER for a in mon.alarms)


# --------------------------------------------------------------------------
# reaction hook: factor -> Topology.degrade -> re-route report
# --------------------------------------------------------------------------

def test_reroute_report_for_degraded_inter():
    spec = sim.EpisodeSpec(name="deg", label="link_degraded", level="inter",
                           fault=sim.FaultSpec(inter_bw_factor=0.4), seed=4)
    mon = _replay(spec)
    rep = mon.reroute(mon.alarms[0])
    # cloud-virtio routes bulk flat on the healthy fabric; a degraded
    # fabric flips bulk buckets to two-level — the report must say so
    assert rep.n_changed > 0
    assert "re-route" in rep.summary()
    assert rep.topo_name == VIRT
    # the re-routed plan is what the router itself would choose on the
    # degraded topology
    deg = hw.TOPOLOGIES[VIRT].degrade(**mon.alarms[0].degrade_kwargs())
    expect = tuple(planner.choose_allreduce_algo(b, 16, deg)
                   for b in BUCKET_BYTES)
    assert rep.new_algos == expect


def test_reroute_report_straggler_is_stable():
    spec = sim.EpisodeSpec(name="st", label="straggler",
                           fault=sim.FaultSpec(straggler_slowdown=2.0),
                           seed=3)
    mon = _replay(spec)
    rep = mon.reroute(mon.alarms[0])
    # compute slowdown does not change link routing
    assert rep.n_changed == 0
    assert "unchanged" in rep.summary()


# --------------------------------------------------------------------------
# monitor construction / misc behavior
# --------------------------------------------------------------------------

def test_from_plan_mesh8(mesh8):
    import jax

    def _tree():
        k = jax.random.PRNGKey(3)
        return {"embed": jax.random.normal(k, (32, 8)),
                "w": jax.random.normal(jax.random.fold_in(k, 1), (64, 16))}

    comm = eng.CommConfig(mode="mlsl", wire="int8", hier=True,
                          topo="xeon-shm-10gbe")
    plan = eng.build_plan(_tree(), comm, mesh8, DATA_AXES)
    mon = detect.HealthMonitor.from_plan(plan)
    assert len(mon.t_model) == plan.n_buckets
    assert all(t > 0 for t in mon.t_model)
    assert mon.topo.name == "xeon-shm-10gbe"
    # replaying the model's own bucket times as "measured" stays silent
    for s in range(40):
        mon.observe_step(s, 0.5)
        if s % 5 == 0:
            mon.observe_bucket_times(s, list(mon.t_model))
    assert mon.alarms == []


def test_step_only_monitor_drift():
    """No bucket model at all (gspmd / serve decode): step drift still
    detected, and only the generic kind fires."""
    cfg = detect.DetectorConfig()
    mon = detect.HealthMonitor(config=cfg)
    for s in range(cfg.warmup_steps):
        mon.observe_step(s, 0.5)
    fired = []
    for s in range(cfg.warmup_steps, cfg.warmup_steps + 10):
        fired += mon.observe_step(s, 1.0)
    assert len(fired) == 1 and fired[0].kind == detect.ALARM_STEP_DRIFT
    # recovery re-arms: back to baseline, then drift again -> a second alarm
    for s in range(30, 40):
        mon.observe_step(s, 0.5)
    fired2 = []
    for s in range(40, 50):
        fired2 += mon.observe_step(s, 1.0)
    assert len(fired2) == 1


def test_bucket_length_mismatch_ignored():
    mon = detect.HealthMonitor(bucket_bytes=BUCKET_BYTES,
                               algos=_routed_algos(), nodes=16, topo=VIRT)
    assert mon.observe_bucket_times(0, [1e-3, 2e-3]) == []


def test_wallclock_preset_is_looser():
    base, wc = detect.DetectorConfig(), detect.DetectorConfig.wallclock()
    assert wc.step_rel_threshold > base.step_rel_threshold
    assert wc.bucket_rel_threshold > base.bucket_rel_threshold
    assert wc.scale_floor > base.scale_floor
    assert wc.step_sustain >= base.step_sustain


def test_episode_true_factor_labels():
    F = sim.FaultSpec
    assert sim.EpisodeSpec(name="c", label="clean").true_factor == 1.0
    assert sim.EpisodeSpec(
        name="s", label="straggler",
        fault=F(straggler_slowdown=1.5)).true_factor == 1.5
    assert sim.EpisodeSpec(
        name="i", label="link_degraded", level="inter",
        fault=F(inter_bw_factor=0.4)).true_factor == 0.4
    assert sim.EpisodeSpec(
        name="a", label="link_degraded", level="intra",
        fault=F(intra_bw_factor=0.25)).true_factor == 0.25
    assert sim.EpisodeSpec(
        name="h", label="link_degraded", level="inter",
        fault=F(hetero_link_bw_factors=(1.0, 0.6, 0.9))).true_factor == 0.6


def test_episode_events_deterministic():
    """Same spec -> bit-identical event stream (the LCG jitter carries no
    platform or library dependence) — the property the gated bench rests
    on."""
    spec = sim.EpisodeSpec(name="d", label="straggler",
                           fault=sim.FaultSpec(straggler_slowdown=1.5),
                           seed=2)
    algos = _routed_algos()
    a = sim.generate_episode(spec, BUCKET_BYTES, algos)
    b = sim.generate_episode(spec, BUCKET_BYTES, algos)
    assert a == b
