import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import ckpt


def test_roundtrip(tmp_path):
    tree = {"a": jnp.arange(12.0).reshape(3, 4),
            "nest": {"b": jnp.ones((5,), jnp.bfloat16),
                     "c": jnp.asarray([1, 2, 3], jnp.int32)},
            "list": [jnp.zeros((2, 2)), jnp.full((1,), 7.0)]}
    d = ckpt.save(str(tmp_path / "ck"), tree, step=42)
    like = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)
    out = ckpt.restore(d, like)
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_array_equal(
            np.asarray(a, np.float32), np.asarray(b, np.float32)), tree, out)
    assert out["nest"]["b"].dtype == jnp.bfloat16
    assert ckpt.latest_step(d) == 42


def test_restore_onto_device(tmp_path):
    tree = {"w": jnp.ones((8, 8))}
    d = ckpt.save(str(tmp_path / "ck"), tree)
    sh = jax.sharding.SingleDeviceSharding(jax.devices()[0])
    out = ckpt.restore(d, tree, shardings={"w": sh})
    assert out["w"].sharding == sh
