"""Observability stack (repro.obs): trace writer, CommStats, step meter.

The PR-9 acceptance claims verified here:

  * the Chrome-trace writer round-trips through write/load/validate and
    nested host spans stay containment-nested;
  * ``export_sim_spans`` carries the simulator's modeled timeline into the
    trace losslessly (span count, per-category totals == IterationStats);
  * ``CommEngine.stats()`` wire bytes exactly match the plan's message
    sizes x wire widths — flat fp32 is ``n_elems * 4`` unpadded, the
    hierarchical int8 fabric gather leg is ``elems * 1`` plus one f32
    scale per QUANT_BLOCK;
  * every stats/meter ledger entry is warn-only by construction
    (informational or unstable) so the perf diff gate cannot trip on it;
  * a mesh8 engine's stats/table/describe agree with the plan.
"""

import json

import jax
import pytest

from repro.configs import cnn_tables
from repro.core import collectives as cl
from repro.core import engine as eng
from repro.core import hier, hw, planner
from repro.core import simulator as sim
from repro.obs import meter as obs_meter
from repro.obs import stats as obs_stats
from repro.obs import trace as obs_trace

DATA_AXES = (hier.NODE_AXIS, hier.LOCAL_AXIS)


def _tree():
    k = jax.random.PRNGKey(3)
    return {"embed": jax.random.normal(k, (32, 8)),
            "w": jax.random.normal(jax.random.fold_in(k, 1), (64, 16)),
            "head": jax.random.normal(jax.random.fold_in(k, 2), (8, 32))}


# --------------------------------------------------------------------------
# trace writer
# --------------------------------------------------------------------------

def test_trace_round_trip(tmp_path):
    w = obs_trace.TraceWriter()
    w.name_process(0, "measured")
    w.name_thread(0, 0, "steps")
    w.complete("step0", 0.0, 100.0, pid=0, tid=0, cat="step",
               args={"loss": 1.0})
    w.instant("ckpt", 50.0)
    path = w.write(str(tmp_path / "trace.json"))
    obj = obs_trace.load_trace(path)
    assert obj["displayTimeUnit"] == "ms"
    names = [e["name"] for e in obj["traceEvents"]]
    assert "step0" in names and "ckpt" in names
    x = next(e for e in obj["traceEvents"] if e["name"] == "step0")
    assert x["ph"] == "X" and x["dur"] == 100.0 and x["args"]["loss"] == 1.0


def test_trace_span_nesting():
    """Host spans nest by containment: inner X interval inside outer's."""
    w = obs_trace.TraceWriter()
    with w.span("outer", cat="step"):
        with w.span("inner", cat="comm"):
            pass
    by_name = {e["name"]: e for e in w.events}
    outer, inner = by_name["outer"], by_name["inner"]
    assert inner["ts"] >= outer["ts"]
    assert inner["ts"] + inner["dur"] <= outer["ts"] + outer["dur"] + 1e-6
    obs_trace.validate_trace(w.to_json())


def test_trace_metadata_dedup_and_negative_dur():
    w = obs_trace.TraceWriter()
    w.name_process(1, "modeled")
    w.name_process(1, "modeled again")          # deduped
    assert sum(e["ph"] == "M" for e in w.events) == 1
    w.complete("clamp", 10.0, -5.0)             # clamped, never invalid
    assert w.events[-1]["dur"] == 0.0
    obs_trace.validate_trace(w.to_json())


def test_validate_trace_rejects_malformed():
    with pytest.raises(ValueError):
        obs_trace.validate_trace({"traceEvents": "nope"})
    with pytest.raises(ValueError):
        obs_trace.validate_trace(
            {"traceEvents": [{"ph": "X", "name": "a", "ts": 0.0}]})  # no dur
    with pytest.raises(ValueError):
        obs_trace.validate_trace(
            {"traceEvents": [{"ph": "B", "name": "a", "ts": 0.0}]})  # no E


def test_trace_counter_round_trip(tmp_path):
    """Counter ("C") events — the tok/s / exposed-share rate tracks — write,
    load, and validate; malformed counters are rejected."""
    w = obs_trace.TraceWriter()
    w.counter("rates", 10.0, {"tokens_per_sec": 123.0,
                              "exposed_comm_share": 0.25})
    w.counter("rates", 20.0, {"tokens_per_sec": 130.0,
                              "exposed_comm_share": 0.20})
    path = w.write(str(tmp_path / "trace.json"))
    obj = obs_trace.load_trace(path)
    cs = [e for e in obj["traceEvents"] if e["ph"] == "C"]
    assert len(cs) == 2
    assert cs[0]["args"]["tokens_per_sec"] == 123.0
    assert cs[1]["args"]["exposed_comm_share"] == 0.20
    for bad in (
        {"ph": "C", "name": "r", "ts": 0.0},                    # no args
        {"ph": "C", "name": "r", "ts": 0.0, "args": {}},        # empty
        {"ph": "C", "name": "r", "ts": 0.0, "args": {"x": "y"}},  # non-num
    ):
        with pytest.raises(ValueError):
            obs_trace.validate_trace({"traceEvents": [bad]})


# --------------------------------------------------------------------------
# modeled-timeline export
# --------------------------------------------------------------------------

def _sim_stats(policy):
    layers = sim.layers_from_specs(cnn_tables.TOPOLOGIES["resnet50"](), 32,
                                   hw.XEON_6148)
    return sim.simulate_iteration(layers, 8, hw.ETH_10G, policy,
                                  record_timeline=True)


@pytest.mark.parametrize("policy", list(sim.Policy))
def test_export_sim_spans_matches_iteration_stats(policy):
    st = _sim_stats(policy)
    assert st.timeline, "record_timeline must fill the timeline"
    w = obs_trace.TraceWriter()
    n = obs_trace.export_sim_spans(st.timeline, w, pid=1, track="modeled")
    assert n == len(st.timeline)
    xs = [e for e in w.events if e["ph"] == "X"]
    assert len(xs) == n and all(e["pid"] == 1 for e in xs)
    # per-category span totals reproduce the IterationStats accounting
    def total(cat):
        return sum(e["dur"] for e in xs if e["cat"] == cat) / 1e6

    assert total("compute") == pytest.approx(st.compute_time, rel=1e-9)
    assert total("comm") == pytest.approx(st.comm_busy, rel=1e-9)
    end = max(e["ts"] + e["dur"] for e in xs) / 1e6
    assert end == pytest.approx(st.total_time, rel=1e-9)
    obs_trace.validate_trace(w.to_json())


@pytest.mark.parametrize("overlap", [False, True])
def test_export_bucket_schedule_timeline(overlap):
    st = sim.simulate_bucket_schedule([1e-3, 2e-3], 4, 5e-3, overlap=overlap,
                                      record_timeline=True)
    assert st.timeline
    w = obs_trace.TraceWriter()
    obs_trace.export_sim_spans(st.timeline, w)
    xs = [e for e in w.events if e["ph"] == "X"]
    comm = sum(e["dur"] for e in xs if e["cat"] == "comm") / 1e6
    assert comm == pytest.approx(st.comm_busy, rel=1e-9)
    end = max(e["ts"] + e["dur"] for e in xs) / 1e6
    assert end == pytest.approx(st.total_time, rel=1e-9)
    # no timeline unless asked: the default stays allocation-free
    off = sim.simulate_bucket_schedule([1e-3], 2, 5e-3, overlap=overlap)
    assert off.timeline == ()


# --------------------------------------------------------------------------
# CommStats wire-byte math
# --------------------------------------------------------------------------

def test_flat_fp32_bytes_exact(mesh8):
    plan = eng.build_plan(_tree(), eng.CommConfig(mode="mlsl", wire="fp32"),
                          mesh8, DATA_AXES)
    st = obs_stats.CommStats.from_plan(plan)
    assert len(st.buckets) == plan.n_buckets
    for b in st.buckets:
        # flat float allreduce: one unpadded message, width 4
        assert b.route == planner.ALGO_FLAT
        assert b.total_bytes == b.n_elems * 4
        assert b.intra_bytes == 0 and b.pad_frac == 0.0


def test_hier_int8_leg_bytes_exact(mesh8):
    comm = eng.CommConfig(mode="mlsl", wire="int8", hier=True,
                          error_feedback=True)
    plan = eng.build_plan(_tree(), comm, mesh8, DATA_AXES)
    st = obs_stats.CommStats.from_plan(plan)
    hier_rows = [b for b in st.buckets if b.route == planner.ALGO_HIER]
    assert hier_rows, "hier plan must route fusable buckets two-level"
    for b in hier_rows:
        rs_i, rs_f, ag_f, ag_i = b.legs
        padded = rs_i.elems
        assert padded % hier._pad_quantum(plan.n_local, plan.n_node,
                                          cl.WIRE_INT8) == 0
        m = padded // plan.n_local
        # intra legs: bf16 (lossy fabric => bf16 intra default), full volume
        assert rs_i.level == ag_i.level == "intra"
        assert rs_i.payload_bytes == ag_i.payload_bytes == padded * 2
        # fabric RS rides bf16: 2 bytes/elem of the 1/local message
        assert rs_f.level == "inter" and rs_f.payload_bytes == 2 * m
        # fabric AG is the int8 wire: 1 byte/elem + one f32 scale per block
        assert ag_f.level == "inter" and ag_f.wire == cl.WIRE_INT8
        assert ag_f.payload_bytes == m * 1
        assert ag_f.scale_bytes == m // cl.QUANT_BLOCK * 4
        assert ag_f.total_bytes == m + m // cl.QUANT_BLOCK * 4
        assert b.ef


def test_nonfusable_falls_back_flat_float(mesh8):
    comm = eng.CommConfig(mode="mlsl", wire="int8", hier=True)
    plan = eng.build_plan(_tree(), comm, mesh8, DATA_AXES,
                          leaf_replicated=lambda path: False)
    st = obs_stats.CommStats.from_plan(plan)
    assert all(not b.fusable for b in st.buckets)
    for b in st.buckets:
        # reduce_chained reduces non-fusable buckets per-leaf on the bf16
        # fallback wire, flat — the stats must mirror that exactly
        assert b.route == planner.ALGO_FLAT and b.wire == cl.WIRE_BF16
        assert b.total_bytes == b.n_elems * 2 and not b.ef


def test_stats_metrics_warn_only(mesh8):
    comm = eng.CommConfig(mode="mlsl", wire="int8", hier=True)
    plan = eng.build_plan(_tree(), comm, mesh8, DATA_AXES)
    ms = obs_stats.CommStats.from_plan(plan, measured=(1e-3,) *
                                       plan.n_buckets).to_metrics()
    assert ms
    for m in ms:
        assert m["better"] is None or m["stable"] is False, m
    names = {m["name"] for m in ms}
    assert "comm_stats/total/total_B" in names
    assert any(n.endswith("/t_measured_us") for n in names)


# --------------------------------------------------------------------------
# engine integration (mesh8)
# --------------------------------------------------------------------------

def test_engine_stats_and_describe(mesh8):
    comm = eng.CommConfig(mode="mlsl", wire="int8", hier=True,
                          topo="xeon-shm-10gbe")
    engine = eng.CommEngine.create(_tree(), comm, mesh8, DATA_AXES)
    st = engine.stats()
    assert len(st.buckets) == engine.plan.n_buckets
    assert st.topo_name == "xeon-shm-10gbe"    # plan's routing topo reused
    assert all(b.t_model is not None and b.t_model > 0 for b in st.buckets)
    table = st.table()
    # one row per bucket + header/sum; describe() is the same table
    assert all(f"\n  {b.index}  " in table or f"\n{b.index}  " in table
               or str(b.n_elems) in table for b in st.buckets)
    assert engine.plan.describe().splitlines()[0] == table.splitlines()[0]


def test_measure_bucket_times_smoke(mesh8):
    from repro import compat
    comm = eng.CommConfig(mode="mlsl", wire="int8", hier=True,
                          error_feedback=True)
    engine = eng.CommEngine.create(_tree(), comm, mesh8, DATA_AXES)
    with compat.set_mesh(mesh8):
        times = obs_stats.measure_bucket_times(engine, mesh8, iters=1,
                                               warmup=1)
    assert len(times) == engine.plan.n_buckets
    assert all(t > 0 for t in times)
    st = engine.stats(measured=times)
    assert st.t_measured_total == pytest.approx(sum(times))


def test_bucket_timer_compile_once_sample_many(mesh8):
    """The telemetry loop's sampled replay: BucketTimer compiles each
    bucket's region once, then repeated sample() calls stay cheap and keep
    producing a full positive per-bucket vector."""
    import time as _time

    from repro import compat
    comm = eng.CommConfig(mode="mlsl", wire="int8", hier=True)
    engine = eng.CommEngine.create(_tree(), comm, mesh8, DATA_AXES)
    with compat.set_mesh(mesh8):
        timer = engine.bucket_timer(mesh8)
        first = timer.sample(warmup=1)           # pays the compiles
        t0 = _time.perf_counter()
        second = timer.sample()
        resample_s = _time.perf_counter() - t0
    assert len(first) == len(second) == engine.plan.n_buckets
    assert all(t > 0 for t in first) and all(t > 0 for t in second)
    # post-compile sampling must be far below any training-step timescale
    assert resample_s < 5.0


# --------------------------------------------------------------------------
# step meter
# --------------------------------------------------------------------------

def test_meter_ema_bias_correction():
    m = obs_meter.StepMeter(ema_decay=0.9, tokens_per_step=100)
    m.update(dt=0.5)
    # after one step the bias-corrected EMA IS the observation
    assert m.step_time == pytest.approx(0.5)
    for _ in range(200):
        m.update(dt=0.5)
    assert m.step_time == pytest.approx(0.5)
    assert m.tokens_per_sec == pytest.approx(200.0)


def test_meter_exposed_frac_and_metrics():
    m = obs_meter.StepMeter()
    assert m.exposed_comm_frac is None
    m.update(dt=0.1, loss=2.0, grad_norm=1.5)
    m.exposed_comm_model = 0.02
    assert m.exposed_comm_frac == pytest.approx(0.2)
    m.exposed_comm_model = 1e9            # model overestimate: capped
    assert m.exposed_comm_frac == 1.0
    assert "loss 2.0000" in m.summary()
    for entry in m.to_metrics():
        assert entry["stable"] is False
    with pytest.raises(ValueError):
        obs_meter.StepMeter().update()    # update without start()


def test_meter_ledger_compatible(tmp_path):
    """Meter + stats entries record cleanly into a schema-valid ledger."""
    from benchmarks import common as bench_common
    m = obs_meter.StepMeter(tokens_per_step=10)
    m.update(dt=0.01)
    led = bench_common.Ledger("obs_test")
    for entry in m.to_metrics():
        led.record(**entry)
    path = led.write(str(tmp_path))
    rec = json.load(open(path))
    bench_common.validate_ledger(rec)
    assert any(e["name"] == "meter/step_time_us" for e in rec["metrics"])
