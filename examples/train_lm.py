"""End-to-end training driver.

Default preset trains a ~2M-param llama-family model for 300 steps on CPU in
a few minutes and reports the loss curve + checkpoint. The `100m` preset is
the same driver at ~100M params (run it on real accelerators; on this CPU
container it is compile-checked but slow).

  PYTHONPATH=src python examples/train_lm.py [--preset tiny|100m]
      [--comm mlsl --wire int8 --error-feedback]
"""

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro import compat
from repro.checkpoint import ckpt
from repro.configs.base import AttnConfig, ModelConfig
from repro.core.planner import Planner
from repro.data import pipeline
from repro.models.transformer import Batch, Model
from repro.optim import optimizers as opt_lib, schedules
from repro.train import trainer as tr

PRESETS = {
    # ~2.4M params: minutes on CPU
    "tiny": dict(n_layers=4, d_model=128, n_heads=4, n_kv=2, d_ff=384,
                 vocab=2048, seq=128, batch=8, steps=300),
    # ~106M params: the assignment's "train ~100M for a few hundred steps"
    # target -- sized for a real device, compile-checked here
    "100m": dict(n_layers=12, d_model=768, n_heads=12, n_kv=4, d_ff=2048,
                 vocab=32000, seq=512, batch=32, steps=300),
}


def build_config(p) -> ModelConfig:
    return ModelConfig(
        name=f"lm-{p['d_model']}", arch_type="dense", n_layers=p["n_layers"],
        d_model=p["d_model"], vocab=p["vocab"], block_pattern=("attn",),
        d_ff=p["d_ff"],
        attn=AttnConfig(n_heads=p["n_heads"], n_kv=p["n_kv"],
                        head_dim=p["d_model"] // p["n_heads"]),
        dtype=jnp.float32, remat=False)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="tiny", choices=sorted(PRESETS))
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--comm", default="mlsl", choices=["gspmd", "mlsl"])
    ap.add_argument("--wire", default="fp32", choices=["fp32", "bf16", "int8"])
    ap.add_argument("--error-feedback", action="store_true")
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--ckpt", default="/tmp/repro_lm_ckpt")
    args = ap.parse_args()

    p = PRESETS[args.preset]
    steps = args.steps or p["steps"]
    cfg = build_config(p)
    model = Model(cfg)
    mesh = compat.make_mesh((1, 1), ("data", "model"),
                            axis_types=(compat.AxisType.Auto,) * 2)
    planner = Planner(mesh=mesh)
    lr = schedules.warmup_cosine(3e-3, steps // 10, steps)
    opt = opt_lib.adamw(lr)
    comm = tr.CommConfig(mode=args.comm, wire=args.wire,
                         error_feedback=args.error_feedback,
                         accum_steps=args.accum)
    data = pipeline.DataConfig(vocab=cfg.vocab, seq_len=p["seq"],
                               global_batch=p["batch"])
    print(f"preset={args.preset} params={model.n_params():,} "
          f"comm={args.comm}/{args.wire} steps={steps}")
    with compat.set_mesh(mesh):
        state = tr.make_train_state(model, opt, jax.random.PRNGKey(0))
        step = jax.jit(tr.make_train_step(model, opt, mesh, planner, comm))
        t0 = time.time()
        for i, raw in enumerate(pipeline.iterate(data, steps)):
            batch = Batch(tokens=jnp.asarray(raw["tokens"]),
                          labels=jnp.asarray(raw["labels"]))
            state, m = step(state, batch)
            if i % 25 == 0 or i == steps - 1:
                print(f"step {i:4d}  loss {float(m['loss']):.4f}  "
                      f"gnorm {float(m['grad_norm']):.2f}  "
                      f"{time.time()-t0:.0f}s", flush=True)
    ckpt.save(args.ckpt, {"params": state.params}, step=steps)
    print(f"saved checkpoint to {args.ckpt}")


if __name__ == "__main__":
    main()
