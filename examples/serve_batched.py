"""Batched serving example: prefill a mixed batch of requests, decode with a
bounded-state model (Mamba2 SSD -- the long_500k-native family), greedy.

  PYTHONPATH=src python examples/serve_batched.py [--arch mamba2-2.7b]
"""

import argparse
import time

import jax
import numpy as np

from repro.configs import registry
from repro.models.transformer import Model
from repro.serve.engine import Engine, EngineConfig, Request, serve_requests


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-2.7b",
                    choices=registry.ARCH_IDS)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--new-tokens", type=int, default=24)
    args = ap.parse_args()

    cfg = registry.get_smoke_config(args.arch)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = Engine(model, params, EngineConfig(max_seq=160))

    rng = np.random.default_rng(0)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab,
                                        size=int(rng.integers(4, 40))).astype(
                                            np.int32),
                    max_new=int(rng.integers(4, args.new_tokens)))
            for _ in range(args.requests)]
    t0 = time.time()
    serve_requests(eng, reqs)
    dt = time.time() - t0
    tok = sum(r.max_new for r in reqs)
    for i, r in enumerate(reqs):
        print(f"req{i}: prompt={len(r.prompt):3d} new={r.max_new:3d} "
              f"-> {r.out[:6].tolist()}...")
    print(f"{tok} tokens in {dt:.1f}s ({tok/dt:.1f} tok/s, "
          f"reduced {cfg.name} on CPU)")


if __name__ == "__main__":
    main()
