"""Quickstart: build a model from the registry, train it with the MLSL comm
stack, and decode from it -- in under a minute on CPU.

  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat
from repro.configs import registry
from repro.core.api import Session
from repro.data import pipeline
from repro.models.transformer import Batch, Model
from repro.optim import optimizers as opt_lib
from repro.serve.engine import Engine, EngineConfig
from repro.train import trainer as tr


def main():
    # 1. any assigned architecture, reduced to laptop scale
    cfg = registry.get_smoke_config("yi-6b")
    model = Model(cfg)
    print(f"model: {cfg.name}  params: {model.n_params():,}")

    # 2. a Session = mesh + planner + MLSL comm config (paper C7)
    mesh = compat.make_mesh((1, 1), ("data", "model"),
                            axis_types=(compat.AxisType.Auto,) * 2)
    sess = Session.create(
        mesh, n_params=model.n_params(),
        comm=tr.CommConfig(mode="mlsl", wire="bf16", prioritize=True))
    print(f"wire saving vs fp32: {sess.wire_savings():.1f}x")

    # 3. train
    opt = opt_lib.adamw(3e-3)
    data = pipeline.DataConfig(vocab=cfg.vocab, seq_len=64, global_batch=8)
    with compat.set_mesh(mesh):
        state = tr.make_train_state(model, opt, jax.random.PRNGKey(0))
        step = jax.jit(sess.make_train_step(model, opt))
        for i, raw in enumerate(pipeline.iterate(data, 40)):
            batch = Batch(tokens=jnp.asarray(raw["tokens"]),
                          labels=jnp.asarray(raw["labels"]))
            state, m = step(state, batch)
            if i % 10 == 0:
                print(f"step {i:3d}  loss {float(m['loss']):.4f}")

    # 4. serve
    eng = Engine(model, state.params, EngineConfig(max_seq=96))
    prompt = np.asarray(pipeline.batch_at(data, 999)["tokens"][:2, :16])
    out = eng.generate(prompt, 8)
    print("generated:", out.tolist())


if __name__ == "__main__":
    main()
