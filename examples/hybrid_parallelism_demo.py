"""The paper's analysis, reproduced interactively (C1/C2/C5):

  1. per-layer C2C ratios for ResNet-50/VGG-16 and what the DL Layer API
     picks (data vs model vs hybrid node groups);
  2. the message-prioritization effect on exposed communication time;
  3. what the planner does with a transformer on the production mesh.

  PYTHONPATH=src python examples/hybrid_parallelism_demo.py
"""

from repro import compat
from repro.configs import cnn_tables, registry
from repro.core import c2c, hw, planner as pl, simulator as sim
from repro.models.transformer import Model


def main():
    print("=== 1. C2C ratios and strategy choice (64 nodes, batch 2048) ===")
    for topo in ("resnet50", "vgg16"):
        layers = cnn_tables.TOPOLOGIES[topo]()
        report = pl.plan_report(layers, batch=2048, p=64)
        interesting = [r for r in report
                       if r.choice.strategy != c2c.Strategy.DATA][:4]
        print(f"{topo}: {len(report)} layers, "
              f"{sum(r.choice.strategy == c2c.Strategy.DATA for r in report)}"
              f" data-parallel")
        for r in interesting:
            print(f"   {r.name:12s} {r.kind:5s} -> {r.choice.strategy.value}"
                  f" (group={r.choice.group_size},"
                  f" ratio={r.choice.ratio:.0f} flop/B)")

    print("\n=== 2. message prioritization (ResNet-50, 64 nodes, 10GbE) ===")
    layers = sim.layers_from_specs(cnn_tables.resnet50_layers(), 32,
                                   hw.XEON_6148)
    for pol in sim.Policy:
        st = sim.simulate_iteration(layers, 64, hw.ETH_10G, pol,
                                    overlap_eff=0.7)
        print(f"   {pol.value:9s} exposed={st.exposed_comm*1e3:7.1f}ms "
              f"total={st.total_time*1e3:7.1f}ms")

    print("\n=== 3. planner on the production mesh (yi-6b) ===")
    mesh = compat.abstract_mesh((16, 16), ("data", "model"))
    model = Model(registry.get_config("yi-6b"))
    planner = pl.make_planner(mesh, model.n_params())
    defs = model.param_defs()
    specs = planner.tree_specs(defs, stacked_paths=Model.stacked_path)
    print(f"   fsdp={planner.fsdp}")
    print(f"   embed  -> {specs['embed']}")
    print(f"   wq     -> {specs['blocks']['p0_attn']['attn']['wq']}")
    print(f"   w2     -> {specs['blocks']['p0_attn']['mlp']['w2']}")
    print(f"   head   -> {specs['head']}")


if __name__ == "__main__":
    main()
