"""The paper's analysis, reproduced interactively — and then executed:

  1. per-layer C2C ratios for ResNet-50/VGG-16 and what the DL Layer API
     picks (data vs model vs hybrid node groups);
  2. the message-prioritization effect on exposed communication time;
  3. the C2C chooser's hybrid plan for a transformer, gated on what the
     mesh can actually execute, with the modeled exposed-comm win;
  4. real hybrid training steps on an 8-device (node=2, local=4) mesh:
     the chooser's model-parallel layers run tensor-parallel over "local"
     through shard_map while gradients reduce data-parallel over "node".

  PYTHONPATH=src python examples/hybrid_parallelism_demo.py
"""

import os

_FLAG = "--xla_force_host_platform_device_count=8"
if _FLAG not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "") + " " + _FLAG).strip()

import jax
import jax.numpy as jnp

from repro import compat
from repro.configs import cnn_tables, registry
from repro.core import c2c, hw, planner as pl, simulator as sim
from repro.data import pipeline
from repro.launch import mesh as mesh_lib
from repro.models.transformer import Batch, Model
from repro.optim import optimizers as opt_lib
from repro.train import trainer as tr


def main():
    print("=== 1. C2C ratios and strategy choice (64 nodes, batch 2048) ===")
    for topo in ("resnet50", "vgg16"):
        layers = cnn_tables.TOPOLOGIES[topo]()
        report = pl.plan_report(layers, batch=2048, p=64)
        interesting = [r for r in report
                       if r.choice.strategy != c2c.Strategy.DATA][:4]
        print(f"{topo}: {len(report)} layers, "
              f"{sum(r.choice.strategy == c2c.Strategy.DATA for r in report)}"
              f" data-parallel")
        for r in interesting:
            print(f"   {r.name:12s} {r.kind:5s} -> {r.choice.strategy.value}"
                  f" (group={r.choice.group_size},"
                  f" ratio={r.choice.ratio:.0f} flop/B)")

    print("\n=== 2. message prioritization (ResNet-50, 64 nodes, 10GbE) ===")
    layers = sim.layers_from_specs(cnn_tables.resnet50_layers(), 32,
                                   hw.XEON_6148)
    for pol in sim.Policy:
        st = sim.simulate_iteration(layers, 64, hw.ETH_10G, pol,
                                    overlap_eff=0.7)
        print(f"   {pol.value:9s} exposed={st.exposed_comm*1e3:7.1f}ms "
              f"total={st.total_time*1e3:7.1f}ms")

    print("\n=== 3. executed hybrid plan (yi-6b smoke, node=2 x local=4) ===")
    cfg = registry.get_smoke_config("yi-6b")
    batch, seq = 8, 64
    amesh = compat.abstract_mesh((2, 4), ("node", "local"))
    plan = pl.plan_hybrid(cfg, amesh, batch=batch, seq=seq)
    for lp in plan.layers:
        note = f" [{lp.reason}]" if lp.reason else ""
        print(f"   {lp.name:12s} {lp.kind:6s} "
              f"chooser={lp.choice.strategy.value}(g={lp.choice.group_size}) "
              f"executed={lp.executed}{note}")
    specs = c2c.layers_from_model_config(cfg, seq)
    cm = pl.model_hybrid_comm(plan, specs, batch=batch, nodes=plan.dp,
                              topo=hw.CLOUD_10G)
    print(f"   modeled exposed comm on {hw.CLOUD_10G.name}: "
          f"pure DP {cm.t_dp_flat*1e3:.2f}ms, "
          f"hybrid {cm.t_hybrid*1e3:.2f}ms "
          f"({cm.reduction_vs_flat:.1f}x less)")

    print("\n=== 4. hybrid training on the real 8-device mesh ===")
    if jax.device_count() < 8:
        print(f"   skipped: {jax.device_count()} devices "
              f"(run without XLA_FLAGS already set)")
        return
    mesh = mesh_lib.make_hier_mesh(2, 4)
    planner = pl.make_hybrid_planner(mesh, cfg, batch=batch, seq=seq)
    model = Model(cfg)
    defs = model.param_defs()
    pspecs = planner.tree_specs(defs, stacked_paths=Model.stacked_path)
    print(f"   wq   -> {pspecs['blocks']['p0_attn']['attn']['wq']}")
    print(f"   wo   -> {pspecs['blocks']['p0_attn']['attn']['wo']}")
    print(f"   embed-> {pspecs['embed']}")
    comm = tr.CommConfig(mode="mlsl", hier=True, topo=hw.CLOUD_10G.name)
    optimizer = opt_lib.make_optimizer("adamw", 3e-3)
    dcfg = pipeline.DataConfig(vocab=cfg.vocab, seq_len=seq,
                               global_batch=batch, seed=0)
    with compat.set_mesh(mesh):
        state = tr.make_train_state(model, optimizer, jax.random.PRNGKey(0))
        step_fn = jax.jit(tr.make_train_step(model, optimizer, mesh, planner,
                                             comm))
        for s, raw in enumerate(pipeline.iterate(dcfg, 3)):
            b = Batch(tokens=jnp.asarray(raw["tokens"]),
                      labels=jnp.asarray(raw["labels"]))
            state, metrics = step_fn(state, b)
            print(f"   step {s} loss {float(metrics['loss']):.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f}")


if __name__ == "__main__":
    main()
