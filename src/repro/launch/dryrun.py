import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input-shape x
mesh) combination against the production mesh, with 512 placeholder host
devices standing in for the chips (no real allocation: all inputs are
ShapeDtypeStructs).

Per combination this records:
  * compile success (the deliverable: the distribution config is coherent),
  * compiled.memory_analysis()  -- proves the per-chip footprint fits,
  * compiled.cost_analysis()    -- FLOPs / bytes for the roofline,
  * parsed collective wire bytes (launch/roofline.py),
  * the roofline terms + dominant bottleneck.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch yi-6b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]
  ... [--comm gspmd|mlsl] [--wire fp32|bf16|int8] [--moe-impl gather|ep]
      [--out artifacts/dryrun]
"""

import argparse
import dataclasses
import json
import time
import traceback
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import compat
from repro.configs import registry
from repro.configs.base import (ModelConfig, active_param_count_estimate,
                                param_count_estimate)
from repro.configs.shapes import SHAPES, InputShape
from repro.core.planner import Planner, make_planner
from repro.launch import mesh as mesh_lib
from repro.launch import roofline as rf
from repro.models import blocks as blocks_lib
from repro.models import common
from repro.models.transformer import Batch, Model
from repro.optim import optimizers as opt_lib
from repro.train import trainer as tr


# --------------------------------------------------------------------------
# input / state specs (ShapeDtypeStructs only -- nothing is allocated)
# --------------------------------------------------------------------------

def _sds(shape, dtype, mesh, spec):
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=NamedSharding(mesh, spec))


def batch_specs(cfg: ModelConfig, shape: InputShape, mesh, planner: Planner,
                *, with_labels: bool) -> Batch:
    B = shape.global_batch
    S = shape.seq_len
    if cfg.vlm_img_tokens:
        S = S - cfg.vlm_img_tokens
    tok = planner.tokens_spec(B, extra_dims=1)
    emb = planner.tokens_spec(B, extra_dims=2)
    return Batch(
        tokens=_sds((B, S), jnp.int32, mesh, tok),
        labels=_sds((B, S), jnp.int32, mesh, tok) if with_labels else None,
        mask=None,
        img_embeds=_sds((B, cfg.vlm_img_tokens, cfg.vlm_d_vision), jnp.bfloat16,
                        mesh, emb) if cfg.vlm_img_tokens else None,
        frame_embeds=_sds((B, cfg.encoder.n_frames, cfg.encoder.d_input),
                          jnp.bfloat16, mesh, emb)
        if cfg.encoder is not None else None)


def param_shardings(model: Model, mesh, planner: Planner):
    return planner.tree_shardings(model.param_defs(),
                                  stacked_paths=Model.stacked_path)


def param_specs_sds(model: Model, mesh, planner: Planner):
    defs = model.param_defs()
    sh = param_shardings(model, mesh, planner)
    return common.abstract_tree(defs, sh)


def train_state_sds(model: Model, optimizer, mesh, planner: Planner):
    params = param_specs_sds(model, mesh, planner)
    opt_shape = jax.eval_shape(optimizer.init, params)
    # optimizer states mirror the parameter shardings
    p_leaves = jax.tree_util.tree_leaves(params)
    opt = jax.tree_util.tree_map(
        lambda s: None, opt_shape)
    opt = {}
    for name, sub in opt_shape.items():
        sub_leaves = jax.tree_util.tree_leaves(sub)
        td = jax.tree_util.tree_structure(sub)
        opt[name] = jax.tree_util.tree_unflatten(
            td, [jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=p.sharding)
                 for l, p in zip(sub_leaves, p_leaves)])
    step = _sds((), jnp.int32, mesh, P())
    return tr.TrainState(params=params, opt_state=opt, step=step,
                         comm_residuals=None)


def cache_spec_tree(cache_shapes, planner: Planner, batch: int, mesh):
    """Assign PartitionSpecs to a decode-cache tree by leaf name."""
    ms, mx = planner.model_size, planner.model_axis
    baxes = planner.batch_spec_axes(batch)
    lead = baxes if len(baxes) > 1 else (baxes[0] if baxes else None)

    def div(n):
        return ms > 1 and n % ms == 0

    def one(path, sds):
        keys = [str(p.key) for p in path if hasattr(p, "key")]
        stacked = "blocks" in keys
        off = 1 if stacked else 0
        name = keys[-1]
        dims = [None] * sds.ndim
        if sds.ndim > off:
            dims[off] = lead
        if name in ("k", "v", "k_s", "v_s"):       # (B, S, KV, hd|1)
            if div(sds.shape[off + 2]):
                dims[off + 2] = mx
            elif div(sds.shape[off + 1]):
                dims[off + 1] = mx
        elif name in ("ckv", "kpe"):               # (B, S, r)
            if div(sds.shape[off + 1]):
                dims[off + 1] = mx
        elif name == "state":                      # (B, H, N, P)
            if div(sds.shape[off + 1]):
                dims[off + 1] = mx
        elif name in ("conv", "conv_x", "conv_B", "conv_C"):  # (B, W-1, C)
            if div(sds.shape[off + 2]):
                dims[off + 2] = mx
        elif name == "h":                          # (B, width)
            if div(sds.shape[off + 1]):
                dims[off + 1] = mx
        return jax.ShapeDtypeStruct(sds.shape, sds.dtype,
                                    sharding=NamedSharding(mesh, P(*dims)))

    return jax.tree_util.tree_map_with_path(one, cache_shapes)


# --------------------------------------------------------------------------
# step builders
# --------------------------------------------------------------------------

def _opt_for(cfg: ModelConfig):
    big = param_count_estimate(cfg) > 100e9
    return opt_lib.adamw(1e-4, state_dtype=jnp.bfloat16 if big else
                         jnp.float32)


def _ctx_kw(cfg: ModelConfig, shape: InputShape, comm: tr.CommConfig,
            mesh, planner: Planner) -> dict:
    kw = {}
    if shape.name == "long_500k" and not cfg.is_native_long:
        kw["window_override"] = cfg.long_context_window
    if comm.moe_impl == "ep":
        kw.update(moe_impl="ep", mesh=mesh, batch_axes=planner.batch_axes,
                  fsdp_axes=planner.batch_axes if planner.fsdp else (),
                  wgather_wire=comm.wgather_wire)
    if comm.kv_chunk and shape.kind != "decode":
        kw["kv_chunk"] = comm.kv_chunk
    if comm.kv_dtype != "native" and shape.kind in ("decode", "prefill"):
        kw["kv_dtype"] = comm.kv_dtype
    return kw


def build_train(cfg, shape, mesh, planner, comm):
    model = Model(cfg)
    optimizer = _opt_for(cfg)
    step_fn = tr.make_train_step(model, optimizer, mesh, planner, comm)
    state = train_state_sds(model, optimizer, mesh, planner)
    batch = batch_specs(cfg, shape, mesh, planner, with_labels=True)
    return step_fn, (state, batch)


def build_prefill(cfg, shape, mesh, planner, comm):
    model = Model(cfg)
    kw = _ctx_kw(cfg, shape, comm, mesh, planner)

    def fn(params, batch):
        logits, cache, _ = model.prefill(params, batch, shape.seq_len, **kw)
        return logits, cache

    params = param_specs_sds(model, mesh, planner)
    batch = batch_specs(cfg, shape, mesh, planner, with_labels=False)
    return fn, (params, batch)


def build_decode(cfg, shape, mesh, planner, comm):
    model = Model(cfg)
    kw = _ctx_kw(cfg, shape, comm, mesh, planner)
    B = shape.global_batch

    def fn(params, cache, token, pos):
        return model.decode_step(params, cache, token, pos, **kw)

    params = param_specs_sds(model, mesh, planner)
    cache_shape = jax.eval_shape(
        lambda: model.init_cache(B, shape.seq_len, **kw))
    cache = cache_spec_tree(cache_shape, planner, B, mesh)
    token = _sds((B, 1), jnp.int32, mesh,
                 planner.tokens_spec(B, extra_dims=1))
    pos = _sds((), jnp.int32, mesh, P())
    return fn, (params, cache, token, pos)


# -- single-superblock steps for layerwise roofline correction --------------

def build_block_step(cfg, shape, mesh, planner, comm, kind_of_step):
    model = Model(cfg)
    kw = _ctx_kw(cfg, shape, comm, mesh, planner)
    B, S = shape.global_batch, shape.seq_len
    if kind_of_step == "train" and comm.accum_steps > 1:
        B = max(B // comm.accum_steps, 1)     # per-microbatch block cost
    if cfg.vlm_img_tokens:
        S = S  # hidden states include image positions; keep S
    ctx = model._ctx(**kw)
    defs = {f"p{i}_{k}": blocks_lib.block_defs(k, cfg)
            for i, k in enumerate(cfg.block_pattern)}
    sh = planner.tree_shardings(defs)
    pspecs = common.abstract_tree(defs, sh)
    hspec = planner.tokens_spec(B, extra_dims=2)
    enc_closure = None
    if cfg.encoder is not None:
        enc_closure = _sds((B, cfg.encoder.n_frames, cfg.d_model),
                           jnp.bfloat16, mesh, hspec)

    if kind_of_step == "train":
        h = _sds((B, S, cfg.d_model), cfg.dtype, mesh, hspec)

        def fn(params, hh, enc=None):
            c = dataclasses.replace(ctx, enc_out=enc)

            def lf(params, hh):
                out = hh
                for i, k in enumerate(cfg.block_pattern):
                    out, _ = blocks_lib.block_apply(k, params[f"p{i}_{k}"],
                                                    out, c)
                return jnp.sum(out.astype(jnp.float32))

            return jax.grad(lf, argnums=(0, 1))(params, hh)

        args = (pspecs, h) + ((enc_closure,) if enc_closure is not None else ())
        return fn, args

    if kind_of_step == "prefill":
        h = _sds((B, S, cfg.d_model), cfg.dtype, mesh, hspec)

        def fn(params, hh, enc=None):
            c = dataclasses.replace(ctx, enc_out=enc)
            for i, k in enumerate(cfg.block_pattern):
                hh, _ = blocks_lib.block_apply(k, params[f"p{i}_{k}"], hh, c)
            return hh

        args = (pspecs, h) + ((enc_closure,) if enc_closure is not None else ())
        return fn, args

    assert kind_of_step == "decode"
    h = _sds((B, 1, cfg.d_model), cfg.dtype, mesh, hspec)
    cache_shape = jax.eval_shape(lambda: {
        f"p{i}_{k}": blocks_lib.block_init_cache(k, cfg, B, shape.seq_len, ctx)
        for i, k in enumerate(cfg.block_pattern)})
    cache = cache_spec_tree(cache_shape, planner, B, mesh)
    pos = _sds((), jnp.int32, mesh, P())

    def fn(params, hh, cch, pp):
        outs = {}
        for i, k in enumerate(cfg.block_pattern):
            key = f"p{i}_{k}"
            hh, outs[key] = blocks_lib.block_decode(k, params[key], hh,
                                                    cch[key], pp, ctx)
        return hh, outs

    return fn, (pspecs, h, cache, pos)


# --------------------------------------------------------------------------
# the dry-run itself
# --------------------------------------------------------------------------

BUILDERS = {"train": build_train, "prefill": build_prefill,
            "decode": build_decode}


def should_skip(cfg: ModelConfig, shape: InputShape) -> Optional[str]:
    if shape.name == "long_500k" and not cfg.supports_long_decode:
        return ("enc-dec full attention (no windowed variant in the family); "
                "see DESIGN.md §5")
    return None


def model_flops_for(cfg: ModelConfig, shape: InputShape) -> float:
    n_active = active_param_count_estimate(cfg)
    if shape.kind == "train":
        return 6.0 * n_active * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n_active * shape.global_batch * shape.seq_len
    return 2.0 * n_active * shape.global_batch          # decode: 1 token


def dryrun_one(arch: str, shape_name: str, *, multi_pod: bool = False,
               comm: tr.CommConfig | None = None,
               with_block_cost: bool = True,
               fsdp_override: Optional[bool] = None,
               parallelism: str = "hybrid",
               minipod: bool = False,
               comm_stats: bool = False,
               telemetry_path: Optional[str] = None) -> dict:
    cfg = registry.get_config(arch)
    shape = SHAPES[shape_name]
    comm = comm or tr.CommConfig()
    if minipod:
        # 64-chip (8, 8) analysis mesh: used for wire-format studies where
        # XLA:CPU cannot compile the manual-mode pattern at 512 partitions
        mesh = compat.make_mesh((8, 8), ("data", "model"),
                                axis_types=(compat.AxisType.Auto,) * 2)
        mesh_name = "minipod8x8"
    else:
        mesh = mesh_lib.make_production_mesh(multi_pod=multi_pod)
        mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    chips = mesh_lib.n_chips(mesh)
    rec: dict = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                 "chips": chips, "comm": dataclasses.asdict(comm)}

    skip = should_skip(cfg, shape)
    if skip:
        rec["status"] = "skipped"
        rec["reason"] = skip
        return rec

    train = shape.kind == "train"
    bpp = (2.0 + 2.0 * (2.0 if param_count_estimate(cfg) > 100e9 else 4.0)
           if train else 2.0)
    planner = make_planner(mesh, param_count_estimate(cfg), train=train,
                           bytes_per_param_state=bpp)
    if parallelism == "dp":
        # paper C2: node-group size 1 -- pure data parallelism with
        # ZeRO-sharded parameters/optimizer over every mesh axis
        planner = Planner(mesh=mesh, fsdp=True, dp_only=True)
    if fsdp_override is not None:
        planner.fsdp = fsdp_override
    rec["fsdp"] = planner.fsdp
    rec["parallelism"] = parallelism
    rec["n_params"] = Model(cfg).n_params()

    if (comm_stats or telemetry_path) and comm.mode == "mlsl" \
            and shape.kind == "train":
        # the bucket plan is pure host math -- record the MLSL-style per-
        # bucket wire stats (repro.obs.stats) alongside the roofline so the
        # dry-run artifact says what each fused bucket would put on the wire
        st = tr.make_comm_engine(Model(cfg), mesh, planner, comm).stats()
        if comm_stats:
            rec["comm_stats"] = {
                "n_buckets": len(st.buckets),
                "topo": st.topo_name,
                "total_bytes": st.total_bytes,
                "intra_bytes": st.intra_bytes,
                "inter_bytes": st.inter_bytes,
                "t_model_total_s": st.t_model_total,
            }
            print(st.table())
        if telemetry_path:
            # healthy modeled baseline card in the telemetry schema: a live
            # run at this config can hand these bucket_times to the health
            # monitor (obs.detect) as the measured-vs-modeled denominator
            from repro.obs import telemetry as obs_telemetry
            with obs_telemetry.TelemetryWriter(
                    telemetry_path,
                    run_info={"source": "dryrun", "arch": arch,
                              "shape": shape_name, "mesh": mesh_name,
                              "topo": st.topo_name,
                              "n_buckets": len(st.buckets)},
                    sample_every=0) as tel:
                tel.bucket_times(
                    0, modeled=[b.t_model or 0.0 for b in st.buckets])
            rec["telemetry"] = telemetry_path

    fn, args = BUILDERS[shape.kind](cfg, shape, mesh, planner, comm)
    t0 = time.time()
    lowered = jax.jit(fn).lower(*args)
    rec["lower_s"] = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    rec["compile_s"] = time.time() - t0

    ma = compiled.memory_analysis()
    rec["memory"] = {
        "argument_bytes": int(ma.argument_size_in_bytes),
        "output_bytes": int(ma.output_size_in_bytes),
        "temp_bytes": int(ma.temp_size_in_bytes),
        "generated_code_bytes": int(ma.generated_code_size_in_bytes),
    }
    ca = compat.cost_analysis(compiled)
    cost_full = {k: float(ca.get(k, 0.0)) for k in ("flops", "bytes accessed")}
    rec["cost_full"] = cost_full

    cost_block = None
    reps = cfg.pattern_repeats
    if with_block_cost and reps > 1:
        bfn, bargs = build_block_step(cfg, shape, mesh, planner, comm,
                                      shape.kind)
        bcompiled = jax.jit(bfn).lower(*bargs).compile()
        bca = compat.cost_analysis(bcompiled)
        cost_block = {k: float(bca.get(k, 0.0))
                      for k in ("flops", "bytes accessed")}
        rec["cost_block"] = cost_block

    hlo = compiled.as_text()
    roof = rf.analyze(arch=arch, shape=shape_name, mesh_name=mesh_name,
                      chips=chips, cost_full=cost_full, cost_block=cost_block,
                      repeats=reps, hlo_text=hlo,
                      model_flops=model_flops_for(cfg, shape),
                      accum=comm.accum_steps if shape.kind == "train" else 1)
    rec["roofline"] = roof.as_dict()
    rec["status"] = "ok"
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=registry.ARCH_IDS)
    ap.add_argument("--shape", choices=sorted(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--minipod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--comm", default="gspmd", choices=["gspmd", "mlsl"])
    ap.add_argument("--wire", default="fp32", choices=["fp32", "bf16", "int8"])
    ap.add_argument("--moe-impl", default="gather", choices=["gather", "ep"])
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--overlap", action="store_true",
                    help="pipeline microbatch reduction (mlsl, --accum > 1)")
    ap.add_argument("--wgather-wire", default="bf16",
                    choices=["bf16", "int8"])
    ap.add_argument("--kv-dtype", default="native",
                    choices=["native", "int8"])
    ap.add_argument("--kv-chunk", type=int, default=0)
    ap.add_argument("--parallelism", default="hybrid",
                    choices=["hybrid", "dp"])
    # observability: with --comm mlsl, print + record the per-bucket
    # CommStats table (repro.obs.stats) for each train combination;
    # --telemetry DIR additionally writes DIR/<tag>.telemetry.jsonl — the
    # modeled-only bucket_times baseline card in the telemetry schema
    ap.add_argument("--stats", action="store_true")
    ap.add_argument("--telemetry", default=None, metavar="DIR")
    ap.add_argument("--tag", default="")
    ap.add_argument("--no-prioritize", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    comm = tr.CommConfig(mode=args.comm, wire=args.wire,
                         prioritize=not args.no_prioritize,
                         moe_impl=args.moe_impl, accum_steps=args.accum,
                         overlap=args.overlap, kv_chunk=args.kv_chunk,
                         wgather_wire=args.wgather_wire,
                         kv_dtype=args.kv_dtype)
    combos = []
    meshes = [args.multi_pod] if not args.both_meshes else [False, True]
    if args.all:
        for arch in registry.ARCH_IDS:
            for shape in SHAPES:
                for mp in meshes:
                    combos.append((arch, shape, mp))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        combos = [(args.arch, args.shape, mp) for mp in meshes]

    os.makedirs(args.out, exist_ok=True)
    if args.telemetry:
        os.makedirs(args.telemetry, exist_ok=True)
    n_ok = n_skip = n_fail = 0
    for arch, shape, mp in combos:
        mesh_tag = ("minipod8x8" if args.minipod
                    else ("pod2x16x16" if mp else "pod16x16"))
        tag = f"{arch}__{shape}__{mesh_tag}"
        if args.tag:
            tag += f"__{args.tag}"
        elif comm.mode != "gspmd" or comm.moe_impl != "gather" \
                or comm.wire != "fp32" or comm.accum_steps != 1 \
                or comm.kv_chunk or args.parallelism != "hybrid":
            tag += (f"__{comm.mode}-{comm.wire}-{comm.moe_impl}"
                    f"-a{comm.accum_steps}{'-ov' if comm.overlap else ''}"
                    f"-kc{comm.kv_chunk}-{args.parallelism}")
        path = os.path.join(args.out, tag + ".json")
        if args.skip_existing and os.path.exists(path):
            print(f"[skip-existing] {tag}")
            continue
        t0 = time.time()
        try:
            rec = dryrun_one(arch, shape, multi_pod=mp, comm=comm,
                             parallelism=args.parallelism,
                             minipod=args.minipod, comm_stats=args.stats,
                             telemetry_path=(os.path.join(
                                 args.telemetry,
                                 tag + ".telemetry.jsonl")
                                 if args.telemetry else None))
        except Exception as e:      # noqa: BLE001 -- record and continue
            rec = {"arch": arch, "shape": shape, "status": "failed",
                   "error": f"{type(e).__name__}: {e}",
                   "traceback": traceback.format_exc()[-4000:]}
        rec["wall_s"] = time.time() - t0
        with open(path, "w") as f:
            json.dump(rec, f, indent=1, default=str)
        st = rec["status"]
        n_ok += st == "ok"
        n_skip += st == "skipped"
        n_fail += st == "failed"
        extra = ""
        if st == "ok":
            r = rec["roofline"]
            extra = (f" dom={r['dominant']} tc={r['t_compute']:.3e}"
                     f" tm={r['t_memory']:.3e} tx={r['t_collective']:.3e}")
        elif st == "failed":
            extra = " " + rec["error"][:160]
        print(f"[{st}] {tag} ({rec['wall_s']:.1f}s){extra}", flush=True)
    print(f"done: ok={n_ok} skipped={n_skip} failed={n_fail}")
    return 0 if n_fail == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
