"""Production mesh construction.

Single pod: (data=16, model=16) == 256 chips (TPU v5e pod).
Multi-pod:  (pod=2, data=16, model=16) == 512 chips; the `pod` axis is an
outer data-parallel axis whose gradient reduction crosses the inter-pod
links (DCN/ICI), which is exactly what the multi-pod dry-run must prove
shards.

`make_production_mesh` is a function (not a module constant) so importing
this module never touches jax device state; only launch/dryrun.py sets
--xla_force_host_platform_device_count before calling it.

In the paper's vocabulary the `model` axis is the NODE GROUP of hybrid
parallelism: model parallelism inside a group of 16, data parallelism across
the 16 (or 2x16) groups.
"""

from __future__ import annotations

import jax

from repro import compat


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes,
                            axis_types=(compat.AxisType.Auto,) * len(axes))


def make_host_mesh(data: int = 1, model: int = 1) -> jax.sharding.Mesh:
    """Small mesh over however many (possibly fake) devices exist locally."""
    return compat.make_mesh((data, model), ("data", "model"),
                            axis_types=(compat.AxisType.Auto,) * 2)


def make_hier_mesh(node: int = 2, local: int = 4,
                   model: int = 1) -> jax.sharding.Mesh:
    """Factored data-parallel mesh for hierarchical collectives.

    ``node`` is the inter-node (fabric) axis, ``local`` the intra-node
    (high-bandwidth) axis; gradient reduction runs two-level over
    ("node", "local"). ``model=1`` keeps a model axis for hybrid plans.
    """
    if model > 1:
        return compat.make_mesh((node, local, model),
                                ("node", "local", "model"),
                                axis_types=(compat.AxisType.Auto,) * 3)
    return compat.make_mesh((node, local), ("node", "local"),
                            axis_types=(compat.AxisType.Auto,) * 2)


def n_chips(mesh: jax.sharding.Mesh) -> int:
    n = 1
    for v in mesh.shape.values():
        n *= v
    return n
