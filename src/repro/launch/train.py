"""Training driver.

Runs real training on whatever devices exist (CPU here; the same code path
drives TPU meshes), with the MLSL comm stack selectable from the CLI:

  PYTHONPATH=src python -m repro.launch.train --arch yi-6b --smoke \
      --steps 50 --comm mlsl --wire int8 --batch 8 --seq 64

--smoke uses the reduced config of the same family; full configs are for
real hardware (the dry-run covers them at mesh scale).
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp

from repro import compat
from repro.checkpoint import ckpt
from repro.configs import registry
from repro.core import planner as pl
from repro.data import pipeline
from repro.launch import mesh as mesh_lib
from repro.models.transformer import Batch, Model
from repro.optim import optimizers as opt_lib, schedules
from repro.train import trainer as tr


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=registry.ARCH_IDS, default="yi-6b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--no-smoke", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--optimizer", default="adamw",
                    choices=sorted(opt_lib.OPTIMIZERS))
    ap.add_argument("--comm", default="gspmd", choices=["gspmd", "mlsl"])
    ap.add_argument("--wire", default="fp32", choices=["fp32", "bf16", "int8"])
    ap.add_argument("--error-feedback", action="store_true")
    ap.add_argument("--no-prioritize", action="store_true")
    ap.add_argument("--data-parallel", type=int, default=1)
    ap.add_argument("--model-parallel", type=int, default=1)
    # two-level collectives over a ("node", "local") factored mesh; needs
    # node*local devices (or XLA_FLAGS=--xla_force_host_platform_device_count)
    ap.add_argument("--hier", action="store_true")
    # execute the C2C chooser's hybrid plan: tensor parallelism over the
    # "local" mesh axis for the layers the chooser sends model-parallel,
    # data parallelism across "node" (implies the hier mesh; needs --comm
    # mlsl)
    ap.add_argument("--hybrid", action="store_true")
    ap.add_argument("--nodes", type=int, default=2)
    ap.add_argument("--local", type=int, default=4)
    ap.add_argument("--wire-intra", default=None,
                    choices=[None, "fp32", "bf16"])
    # name a machine hierarchy (repro.core.hw.TOPOLOGIES) to let the
    # per-level cost model route each bucket flat vs two-level
    ap.add_argument("--topo", default=None)
    # MLSL-style compute/communication overlap: with --microbatches N > 1
    # the engine reduces microbatch k's buckets interleaved with microbatch
    # k+1's forward/backward (requires --comm mlsl)
    ap.add_argument("--overlap", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1,
                    help="gradient-accumulation microbatches per step")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    # observability (repro.obs): --stats prints the MLSL-style per-bucket
    # CommStats table + step meter and writes them into the perf ledger
    # (BENCH_comm_stats.json in $BENCH_DIR); --trace DIR writes a Chrome-
    # trace JSON (DIR/trace.json, Perfetto-loadable) with measured step +
    # per-bucket spans beside the modeled schedule for the same config.
    # Both block on every step's result to time it (small overhead).
    ap.add_argument("--stats", action="store_true")
    ap.add_argument("--trace", default=None, metavar="DIR")
    # streaming telemetry + online health monitor (repro.obs.telemetry /
    # repro.obs.detect): --telemetry DIR leaves a schema-versioned JSONL
    # (DIR/telemetry.jsonl — step time, tok/s, modeled exposed-comm share,
    # sampled per-bucket reduce times) and watches the run for sustained
    # measured-vs-modeled drift (straggler / link_degraded /
    # step_time_drift alarms, also surfaced in the post-run table). The
    # per-bucket replay runs BETWEEN steps every --telemetry-sample steps
    # (default 25, 0 disables it), so the hot step path is never perturbed
    # beyond the same per-step blocking --stats already does.
    ap.add_argument("--telemetry", default=None, metavar="DIR")
    ap.add_argument("--telemetry-sample", type=int, default=None,
                    metavar="N",
                    help="bucket-replay sampling period in steps for "
                         "--telemetry (default 25; 0 disables the replay)")
    args = ap.parse_args()

    cfg = (registry.get_smoke_config(args.arch) if args.smoke
           else registry.get_config(args.arch))
    model = Model(cfg)
    if args.hybrid:
        if args.comm != "mlsl":
            raise SystemExit("--hybrid needs --comm mlsl (the activation "
                             "f/g collectives run in the explicit data path)")
        mesh = mesh_lib.make_hier_mesh(args.nodes, args.local)
        planner = pl.make_hybrid_planner(mesh, cfg, batch=args.batch,
                                         seq=args.seq)
        for lp in planner.hybrid.layers:
            note = f" [{lp.reason}]" if lp.reason else ""
            print(f"plan {lp.name:12s} {lp.kind:6s} "
                  f"chooser={lp.choice.strategy.value}"
                  f"(g={lp.choice.group_size}) "
                  f"executed={lp.executed}{note}")
    elif args.hier:
        mesh = mesh_lib.make_hier_mesh(args.nodes, args.local,
                                       args.model_parallel)
        planner = pl.Planner(mesh=mesh)
    else:
        mesh = mesh_lib.make_host_mesh(args.data_parallel,
                                       args.model_parallel)
        planner = pl.Planner(mesh=mesh)
    lr = schedules.warmup_cosine(args.lr, max(args.steps // 10, 1), args.steps)
    optimizer = opt_lib.make_optimizer(args.optimizer, lr)
    comm = tr.CommConfig(mode=args.comm, wire=args.wire,
                         prioritize=not args.no_prioritize,
                         error_feedback=args.error_feedback,
                         hier=args.hier or args.hybrid,
                         wire_intra=args.wire_intra,
                         topo=args.topo, accum_steps=args.microbatches,
                         overlap=args.overlap)
    dcfg = pipeline.DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                               global_batch=args.batch, seed=args.seed)

    meter = tracer = None
    if args.stats or args.trace or args.telemetry:
        from repro.obs import meter as obs_meter
        from repro.obs import trace as obs_trace
        meter = obs_meter.StepMeter(tokens_per_step=args.batch * args.seq)
        if args.trace:
            tracer = obs_trace.TraceWriter()
            tracer.name_process(0, "measured")
            tracer.name_thread(0, 0, "train steps")

    telem = monitor = timer = tel_engine = None
    t_model_tel: list = []
    n_micro = max(args.microbatches, 1)
    if args.telemetry:
        from repro.core import simulator as sim_lib
        from repro.obs import detect as obs_detect
        from repro.obs import telemetry as obs_telemetry
        os.makedirs(args.telemetry, exist_ok=True)
        sample_every = (obs_telemetry.DEFAULT_SAMPLE_EVERY
                        if args.telemetry_sample is None
                        else args.telemetry_sample)
        telem = obs_telemetry.TelemetryWriter(
            os.path.join(args.telemetry, "telemetry.jsonl"),
            run_info={"source": "train", "arch": cfg.name,
                      "comm": args.comm, "wire": args.wire,
                      "mesh": dict(mesh.shape), "batch": args.batch,
                      "seq": args.seq, "steps": args.steps},
            sample_every=sample_every)
        # live detection runs on the de-tuned wall-clock preset: CPU step
        # times jitter far more than the simulator's episodes
        wcfg = obs_detect.DetectorConfig.wallclock()
        if args.comm == "mlsl":
            tel_engine = tr.make_comm_engine(model, mesh, planner, comm)
            monitor = obs_detect.HealthMonitor.from_plan(tel_engine.plan,
                                                         config=wcfg)
            t_model_tel = list(monitor.t_model)
        else:
            # gspmd's reductions are partitioner-inserted, not bucket
            # messages: only the generic step_time_drift alarm is reachable
            monitor = obs_detect.HealthMonitor(config=wcfg)

    with compat.set_mesh(mesh):
        state = tr.make_train_state(model, optimizer,
                                    jax.random.PRNGKey(args.seed))
        step_fn = jax.jit(tr.make_train_step(model, optimizer, mesh, planner,
                                             comm))
        print(f"arch={cfg.name} params={model.n_params():,} comm={args.comm}"
              f"/{args.wire} mesh={dict(mesh.shape)}")
        t0 = time.time()
        for s, raw in enumerate(pipeline.iterate(dcfg, args.steps)):
            kw = {}
            if cfg.vlm_img_tokens:
                kw["img_embeds"] = jnp.zeros(
                    (args.batch, cfg.vlm_img_tokens, cfg.vlm_d_vision),
                    jnp.float32)
            if cfg.encoder is not None:
                kw["frame_embeds"] = jnp.zeros(
                    (args.batch, cfg.encoder.n_frames, cfg.encoder.d_input),
                    jnp.float32)
            batch = Batch(tokens=jnp.asarray(raw["tokens"]),
                          labels=jnp.asarray(raw["labels"]), **kw)
            if meter is not None:
                # metering blocks on each step's result (async dispatch would
                # attribute step k's time to k+1); span per step when tracing
                meter.start()
                if tracer is not None:
                    with tracer.span(f"step{s}", cat="step"):
                        state, metrics = step_fn(state, batch)
                        jax.block_until_ready(metrics)
                else:
                    state, metrics = step_fn(state, batch)
                    jax.block_until_ready(metrics)
                meter.update(loss=float(metrics["loss"]),
                             grad_norm=float(metrics["grad_norm"]))
                if t_model_tel:
                    # modeled exposed-comm share at the CURRENT measured
                    # compute scale (pure host math, a few buckets)
                    meter.exposed_comm_model = \
                        sim_lib.simulate_bucket_schedule(
                            t_model_tel, n_micro,
                            meter.step_time / n_micro,
                            overlap=comm.overlap).exposed_comm
                exposed = meter.exposed_comm_frac
                if tracer is not None:
                    vals = {"tokens_per_sec": meter.tokens_per_sec}
                    if exposed is not None:
                        vals["exposed_comm_share"] = exposed
                    tracer.counter("rates", tracer.now_us(), vals)
                if telem is not None:
                    telem.step(step=s, t_step_s=meter.last_dt,
                               tok_s=meter.tokens_per_sec,
                               loss=meter.last_loss, exposed_frac=exposed)
                    fired = monitor.observe_step(s, meter.last_dt,
                                                 exposed_frac=exposed)
                    if tel_engine is not None and telem.should_sample(s):
                        # sampled standalone replay BETWEEN steps — the hot
                        # path never runs it; first sample pays the compile
                        if timer is None:
                            timer = tel_engine.bucket_timer(mesh)
                            sampled = timer.sample(warmup=1)
                        else:
                            sampled = timer.sample()
                        telem.bucket_times(s, sampled, modeled=t_model_tel)
                        fired += monitor.observe_bucket_times(s, sampled)
                    for a in fired:
                        telem.alarm(step=a.step, kind=a.kind,
                                    factor=a.factor, level=a.level,
                                    rank=a.rank, detail=a.detail)
            else:
                state, metrics = step_fn(state, batch)
            if s % args.log_every == 0 or s == args.steps - 1:
                if meter is not None:
                    print(f"{meter.summary()} ({time.time() - t0:.1f}s)",
                          flush=True)
                else:
                    print(f"step {s:5d} loss {float(metrics['loss']):.4f} "
                          f"gnorm {float(metrics['grad_norm']):.3f} "
                          f"({time.time() - t0:.1f}s)", flush=True)
        if args.stats or tracer is not None:
            _emit_observability(args, mesh, planner, comm, model, meter,
                                tracer, engine=tel_engine)
        if telem is not None:
            telem.close()
            print(f"telemetry: {telem.path} ({telem.n_records} records)")
            _report_health(monitor)
    if args.ckpt_dir:
        ckpt.save(args.ckpt_dir, {"params": state.params}, step=args.steps)
        print(f"checkpoint -> {args.ckpt_dir}")
    return 0


def _report_health(monitor) -> None:
    """Post-run alarm table for --telemetry (the operator's summary)."""
    if not monitor.alarms:
        print("health: no alarms")
        return
    print(f"health: {len(monitor.alarms)} alarm(s)")
    for a in monitor.alarms:
        print(f"  {a.describe()}")
        if monitor.bucket_bytes:
            print(f"    -> {monitor.reroute(a).summary()}")


def _emit_observability(args, mesh, planner, comm, model, meter, tracer,
                        engine=None):
    """Post-run stats/trace emission (--stats / --trace).

    For the mlsl data path: replay each bucket's exchange standalone to get
    measured per-bucket service times, print the CommStats table, write the
    comm_stats entries into the perf ledger (BENCH_comm_stats.json — all
    informational/unstable, never gated), and lay measured per-bucket spans
    plus the MODELED bucket schedule for the same config side by side in
    the trace so Perfetto shows measured-vs-modeled in one view.
    """
    from repro.core import simulator as sim
    from repro.obs import stats as obs_stats
    from repro.obs import trace as obs_trace

    st = None
    if args.comm == "mlsl":
        if engine is None:
            engine = tr.make_comm_engine(model, mesh, planner, comm)
        measured = obs_stats.measure_bucket_times(engine, mesh, iters=2)
        st = engine.stats(measured=measured)
        if tracer is not None:
            tracer.name_thread(0, 1, "bucket replay")
            t_us = tracer.now_us()
            for b in st.buckets:
                dur = (b.t_measured or 0.0) * 1e6
                tracer.complete(
                    f"bucket{b.index}/{b.route}_allreduce_{b.wire}",
                    t_us, dur, pid=0, tid=1, cat="comm",
                    args={"elems": b.n_elems, "total_B": b.total_bytes})
                t_us += dur
        # the modeled schedule for this config: per-bucket cost-model times
        # through the engine's own microbatch pipeline, at the measured
        # compute scale when a meter ran
        n_micro = max(comm.accum_steps, 1)
        micro_compute = (meter.step_time / n_micro
                         if meter is not None and meter.steps else 1e-3)
        modeled = sim.simulate_bucket_schedule(
            [b.t_model or 0.0 for b in st.buckets], n_micro, micro_compute,
            overlap=comm.overlap, record_timeline=True)
        if meter is not None:
            meter.exposed_comm_model = modeled.exposed_comm
        if tracer is not None:
            obs_trace.export_sim_spans(modeled.timeline, tracer, pid=1,
                                       track=f"modeled ({st.topo_name})")
        if args.stats:
            print(st.table())
    elif args.stats:
        print("stats: per-bucket CommStats need --comm mlsl (gspmd's "
              "reductions are partitioner-inserted, not bucket messages)")
    if args.stats and meter is not None and meter.steps:
        print(meter.summary())

    if args.stats:
        try:
            from benchmarks import common as bench_common
        except ImportError:
            bench_common = None     # repo root not on sys.path
        if bench_common is not None:
            led = bench_common.Ledger("comm_stats")
            for m in (st.to_metrics() if st is not None else []):
                led.record(**m)
            if meter is not None and meter.steps:
                for m in meter.to_metrics():
                    led.record(**m)
            print(f"stats ledger: {led.write()}")

    if tracer is not None:
        os.makedirs(args.trace, exist_ok=True)
        path = tracer.write(os.path.join(args.trace, "trace.json"))
        print(f"trace: {path} (open in https://ui.perfetto.dev)")


if __name__ == "__main__":
    raise SystemExit(main())
