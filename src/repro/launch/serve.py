"""Serving driver: batched prefill + decode with a reduced model on local
devices (the full-config serving path is exercised by the dry-run).

  PYTHONPATH=src python -m repro.launch.serve --arch mamba2-2.7b \
      --batch 4 --prompt-len 32 --new-tokens 32
"""

from __future__ import annotations

import argparse
import os
import time

import jax
import numpy as np

from repro.configs import registry
from repro.models.transformer import Model
from repro.serve.engine import Engine, EngineConfig, Request, serve_requests


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=registry.ARCH_IDS, default="yi-6b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--long-context", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    # observability: --stats prints the decode step meter (EMA step time,
    # tok/s); --trace DIR writes DIR/trace.json with prefill + per-decode-
    # step spans and a tok/s counter track (Perfetto-loadable); --telemetry
    # DIR streams DIR/telemetry.jsonl (one step record per decode step) and
    # watches the decode step times for sustained drift
    # (obs.detect step_time_drift — the decode path has no bucket model).
    # All of them block per decode step to time it.
    ap.add_argument("--stats", action="store_true")
    ap.add_argument("--trace", default=None, metavar="DIR")
    ap.add_argument("--telemetry", default=None, metavar="DIR")
    args = ap.parse_args()

    meter = tracer = telem = monitor = None
    if args.stats or args.trace or args.telemetry:
        from repro.obs import meter as obs_meter
        from repro.obs import trace as obs_trace
        meter = obs_meter.StepMeter()
        if args.trace:
            tracer = obs_trace.TraceWriter()
            tracer.name_process(0, "serve")
        if args.telemetry:
            from repro.obs import detect as obs_detect
            from repro.obs import telemetry as obs_telemetry
            os.makedirs(args.telemetry, exist_ok=True)
            telem = obs_telemetry.TelemetryWriter(
                os.path.join(args.telemetry, "telemetry.jsonl"),
                run_info={"source": "serve", "arch": args.arch,
                          "batch": args.batch,
                          "new_tokens": args.new_tokens},
                sample_every=0)   # no bucket replay on the decode path
            monitor = obs_detect.HealthMonitor(
                config=obs_detect.DetectorConfig.wallclock())

    cfg = registry.get_smoke_config(args.arch)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    eng = Engine(model, params, EngineConfig(
        max_seq=args.prompt_len + args.new_tokens + 8,
        temperature=args.temperature, long_context=args.long_context),
        meter=meter, tracer=tracer, telemetry=telem, monitor=monitor)

    rng = np.random.default_rng(args.seed)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab,
                                        size=rng.integers(
                                            4, args.prompt_len + 1)).astype(
                                                np.int32),
                    max_new=args.new_tokens) for _ in range(args.batch)]
    t0 = time.time()
    serve_requests(eng, reqs)
    dt = time.time() - t0
    total_new = sum(r.max_new for r in reqs)
    for i, r in enumerate(reqs):
        print(f"req{i}: prompt_len={len(r.prompt)} -> {r.out[:8].tolist()}...")
    print(f"{total_new} tokens in {dt:.2f}s "
          f"({total_new / dt:.1f} tok/s batched on CPU, reduced config)")
    if args.stats and meter is not None and meter.steps:
        print(f"decode {meter.summary()}")
    if telem is not None:
        telem.close()
        print(f"telemetry: {telem.path} ({telem.n_records} records)")
        if monitor.alarms:
            print(f"health: {len(monitor.alarms)} alarm(s)")
            for a in monitor.alarms:
                print(f"  {a.describe()}")
        else:
            print("health: no alarms")
    if tracer is not None:
        os.makedirs(args.trace, exist_ok=True)
        path = tracer.write(os.path.join(args.trace, "trace.json"))
        print(f"trace: {path} (open in https://ui.perfetto.dev)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
