"""Roofline-term extraction from compiled dry-run artifacts.

Per (arch x shape x mesh) we derive, with TPU v5e constants:

    compute term    = HLO_FLOPs   / (chips x 197 TFLOP/s)
    memory term     = HLO_bytes   / (chips x 819 GB/s)
    collective term = wire_bytes  / (chips x 50 GB/s/link)

HLO_FLOPs / HLO_bytes come from ``compiled.cost_analysis()``. XLA counts a
``while`` body ONCE, so scanned-layer programs are corrected layerwise: the
caller also lowers a single-superblock step and we add (repeats-1) x its
cost (DESIGN.md §6).

Wire bytes are parsed from the HLO text: every all-reduce / all-gather /
reduce-scatter / all-to-all / collective-permute contributes its ring-
algorithm per-chip wire volume, with replica-group sizes parsed per op and
while-body ops multiplied by the trip count.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Optional

from repro.core import hw

PEAK_FLOPS = hw.TPU_V5E.peak_flops          # 197e12 bf16
HBM_BW = hw.TPU_V5E.mem_bw                  # 819e9
LINK_BW = hw.ICI_LINK.bw                    # 50e9

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
}

_COLL = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
         "collective-permute")
# e.g.:  %ag = bf16[2,128]{1,0} all-gather(%x), replica_groups=...
_OP_RE = re.compile(
    r"=\s*(?:\()?\s*(\w+)\[([\d,]*)\][^\s]*\s+"
    r"(all-reduce-start|all-reduce|all-gather-start|all-gather|"
    r"reduce-scatter|all-to-all|collective-permute-start|collective-permute)"
    r"\(")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9, ]*)\}")


def _shape_bytes(dtype: str, dims: str) -> float:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        g = [x for x in m.group(1).split(",") if x.strip()]
        return max(len(g), 1)
    return default


def _wire_bytes(kind: str, nbytes: float, p: int) -> float:
    """Per-chip ring wire volume for one collective of output size nbytes."""
    if p <= 1:
        return 0.0
    if kind.startswith("all-reduce"):
        return 2.0 * nbytes * (p - 1) / p
    if kind.startswith("all-gather"):
        return nbytes * (p - 1) / p            # nbytes == gathered output
    if kind == "reduce-scatter":
        return nbytes * (p - 1)                 # nbytes == scattered output
    if kind == "all-to-all":
        return nbytes * (p - 1) / p
    if kind.startswith("collective-permute"):
        return nbytes
    return 0.0


def _computation_spans(text: str) -> dict:
    """Map computation name -> [start, end) line span in the HLO text."""
    lines = text.splitlines()
    spans = {}
    cur, start = None, 0
    for i, l in enumerate(lines):
        m = re.match(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\([^)]*\)\s*->", l)
        if m and ("{" in l or (i + 1 < len(lines) and lines[i + 1].strip() == "{")):
            if cur is not None:
                spans[cur] = (start, i)
            cur, start = m.group(1), i
    if cur is not None:
        spans[cur] = (start, len(lines))
    return spans


def _while_bodies(text: str) -> set:
    """Names of computations used as while bodies (and their conditions)."""
    out = set()
    for m in re.finditer(r"body=%?([\w.\-]+)", text):
        out.add(m.group(1))
    return out


def _reachable(text: str, spans: dict, roots: set) -> set:
    """Computations reachable from `roots` via calls/fusion references."""
    lines = text.splitlines()
    names = set(spans)
    out = set()
    work = list(roots)
    while work:
        r = work.pop()
        if r in out or r not in spans:
            continue
        out.add(r)
        s, e = spans[r]
        body = "\n".join(lines[s:e])
        for m in re.finditer(r"(?:calls=|to_apply=|body=|condition=)%?([\w.\-]+)",
                             body):
            if m.group(1) in names:
                work.append(m.group(1))
    return out


def _loop_depths(hlo_text: str, spans: dict) -> dict:
    """Loop-nesting depth per computation (0 == not inside any while body).

    Built from `body=`/`condition=` edges (depth+1) and plain call/fusion
    edges (same depth), iterated to fixpoint."""
    lines = hlo_text.splitlines()
    # collect edges: (caller_comp, callee_comp, is_loop_entry)
    line_comp = {}
    for name, (st, en) in spans.items():
        for i in range(st, en):
            line_comp[i] = name
    edges = []
    for i, line in enumerate(lines):
        caller = line_comp.get(i)
        if caller is None:
            continue
        for m in re.finditer(r"(body=|condition=|calls=|to_apply=)"
                             r"%?([\w.\-]+)", line):
            kind, callee = m.groups()
            if callee in spans:
                edges.append((caller, callee,
                              kind in ("body=", "condition=")))
    depth = {name: 0 for name in spans}
    for _ in range(32):                      # fixpoint over nesting levels
        changed = False
        for caller, callee, is_loop in edges:
            d = depth.get(caller, 0) + (1 if is_loop else 0)
            if d > depth.get(callee, 0):
                depth[callee] = d
                changed = True
        if not changed:
            break
    return depth


def collective_wire_bytes(hlo_text: str, *, n_chips: int,
                          loop_mult: float = 1.0,
                          outer_mult: float = 1.0) -> dict:
    """Sum per-chip wire bytes by collective kind.

    Trip counts by loop-nesting depth: depth-1 while bodies get
    `outer_mult` (the accumulation loop when present, else `loop_mult`);
    depth>=2 bodies get `outer_mult * loop_mult` (layer scan nested inside
    the accumulation scan). With no accumulation, outer_mult == 1 and any
    loop depth gets `loop_mult` (the layer scan)."""
    spans = _computation_spans(hlo_text)
    depth = _loop_depths(hlo_text, spans)
    lines = hlo_text.splitlines()
    line_comp = {}
    for name, (st, en) in spans.items():
        for i in range(st, en):
            line_comp[i] = name
    has_outer = outer_mult > 1.0
    totals: dict = {k: 0.0 for k in _COLL}
    counts: dict = {k: 0 for k in _COLL}
    for i, line in enumerate(lines):
        m = _OP_RE.search(line)
        if not m:
            continue
        dtype, dims, kind = m.groups()
        base = next(k for k in _COLL if kind.startswith(k))
        nbytes = _shape_bytes(dtype, dims)
        p = _group_size(line, n_chips)
        d = depth.get(line_comp.get(i), 0)
        if d == 0:
            mult = 1.0
        elif has_outer:
            mult = outer_mult if d == 1 else outer_mult * loop_mult
        else:
            mult = loop_mult
        totals[base] += _wire_bytes(kind, nbytes, p) * mult
        counts[base] += 1
    totals["_counts"] = counts
    return totals


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float            # whole-program, loop-corrected, global
    hlo_bytes: float
    wire_bytes: float           # per-chip
    model_flops: float          # 6*N(active)*D
    t_compute: float
    t_memory: float
    t_collective: float
    dominant: str
    useful_ratio: float
    by_kind: dict

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


def analyze(*, arch: str, shape: str, mesh_name: str, chips: int,
            cost_full: dict, cost_block: Optional[dict], repeats: int,
            hlo_text: str, model_flops: float, accum: int = 1,
            extra_block_collectives: bool = True) -> Roofline:
    """Assemble roofline terms (see module docstring for the methodology)."""
    flops = float(cost_full.get("flops", 0.0))
    bts = float(cost_full.get("bytes accessed", 0.0))
    # cost_block is lowered at the MICROBATCH size; whole-program totals add
    # (accum * repeats - 1) of it on top of the once-counted loop bodies.
    n_blocks_total = repeats * max(accum, 1)
    if cost_block is not None and n_blocks_total > 1:
        flops += (n_blocks_total - 1) * float(cost_block.get("flops", 0.0))
        bts += (n_blocks_total - 1) * float(cost_block.get("bytes accessed",
                                                           0.0))
    colls = collective_wire_bytes(hlo_text, n_chips=chips,
                                  loop_mult=float(repeats),
                                  outer_mult=float(max(accum, 1)))
    wire = sum(v for k, v in colls.items() if not k.startswith("_"))
    # cost_analysis on an SPMD-partitioned executable reports PER-CHIP flops
    # and bytes (verified against per-chip parameter/optimizer footprints);
    # wire bytes from the partitioned HLO are likewise per-chip. So every
    # term is per-chip seconds directly -- equivalent to the brief's
    # global/(chips * rate) formulation.
    t_comp = flops / PEAK_FLOPS
    t_mem = bts / HBM_BW
    t_coll = wire / LINK_BW
    dom = max((("compute", t_comp), ("memory", t_mem),
               ("collective", t_coll)), key=lambda kv: kv[1])[0]
    return Roofline(arch=arch, shape=shape, mesh=mesh_name, chips=chips,
                    hlo_flops=flops, hlo_bytes=bts, wire_bytes=wire,
                    model_flops=model_flops, t_compute=t_comp, t_memory=t_mem,
                    t_collective=t_coll, dominant=dom,
                    useful_ratio=(model_flops / (flops * chips)
                                  if flops else 0.0),
                    by_kind={k: v for k, v in colls.items()
                             if not k.startswith("_")} |
                            {"_counts": colls["_counts"]})
