"""CommStats: MLSL-style per-message statistics for an EnginePlan.

The paper's proof points (§4) are per-message numbers — how many bytes each
gradient message put on which link, under which algorithm, and how long it
took — that only the library owning the exchange can produce. This module
derives exactly that report from an ``EnginePlan``:

  * per-bucket wire legs (``LegBytes``): what each phase of the routed
    collective actually carries — flat ring vs two-level, intra vs inter
    level, fp32/bf16/int8 payload after quantization plus the f32 scale
    sideband, including the tiling padding the int8 wire adds;
  * modeled service time from the ``hw.Topology`` cost model (the same
    ``planner.bucket_allreduce_times`` the router and benchmarks use);
  * measured service time from ``measure_bucket_times`` — a per-bucket
    replay of the engine's own ``_reduce_bucket`` data path on the mesh.

Byte convention: ``LegBytes`` counts the MESSAGE each leg carries (payload
+ scale sideband), not per-hop ring traffic — so a flat fp32 bucket is
exactly ``n_elems * 4`` bytes and the hierarchical int8 fabric gather leg is
exactly ``elems * 1 + scale_bytes``, assertable against the plan.

Surfaced as ``EnginePlan.describe()`` / ``CommEngine.stats()`` (lazy
imports on the core side keep the layering acyclic: this module sits ABOVE
``repro.core``) and serialized into the perf-ledger schema via
``to_metrics()`` — every stats metric is informational (``better=None``) or
unstable (wall-clock), so the ledger diff gate warns and never fails on it.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Optional

from repro.core import collectives as cl
from repro.core import hier as hier_lib
from repro.core import hw
from repro.core import planner as planner_lib

_SCALE_BYTES = 4  # one f32 scale per QUANT_BLOCK elements on the int8 wire


def _roundup(n: int, quantum: int) -> int:
    return ((n + quantum - 1) // quantum) * quantum


def _float_bytes(wire: str) -> int:
    return 2 if wire == cl.WIRE_BF16 else 4


@dataclasses.dataclass(frozen=True)
class LegBytes:
    """One phase of a routed collective: the message it carries."""

    leg: str             # "allreduce" | "reduce_scatter" | "all_gather"
    level: str           # "intra" (node-local link) | "inter" (fabric)
    wire: str            # payload dtype on the wire: fp32 | bf16 | int8
    elems: int           # elements in this leg's message (incl. padding)
    payload_bytes: int
    scale_bytes: int = 0  # f32 scale sideband (int8 payload only)

    @property
    def total_bytes(self) -> int:
        return self.payload_bytes + self.scale_bytes


def _flat_legs(n_elems: int, wire: str, dp: int) -> tuple:
    """Legs of `collectives.allreduce` over `dp` ranks (the flat route)."""
    if wire == cl.WIRE_INT8:
        # _allreduce_int8: pad to whole (TILE_ROWS x QUANT_BLOCK) rows per
        # rank, reduce-scatter bf16, all-gather int8 + f32 block scales
        padded = _roundup(n_elems, dp * cl.QUANT_BLOCK * 8)
        return (
            LegBytes("reduce_scatter", "inter", cl.WIRE_BF16, padded,
                     2 * padded),
            LegBytes("all_gather", "inter", cl.WIRE_INT8, padded, padded,
                     padded // cl.QUANT_BLOCK * _SCALE_BYTES),
        )
    # float wires psum the message unpadded: exactly n_elems * width bytes
    return (LegBytes("allreduce", "inter", wire, n_elems,
                     n_elems * _float_bytes(wire)),)


def _hier_legs(n_elems: int, spec: hier_lib.HierSpec, local: int,
               node: int) -> tuple:
    """Legs of `hier.hier_allreduce`: intra RS -> fabric allreduce on
    1/local of the volume -> intra AG, per-leg wire precision."""
    padded = _roundup(n_elems,
                      hier_lib._pad_quantum(local, node, spec.wire_inter))
    isz = _float_bytes(spec.wire_intra)
    m = padded // local                       # fabric-leg message
    legs = [LegBytes("reduce_scatter", "intra", spec.wire_intra, padded,
                     padded * isz)]
    if spec.wire_inter == cl.WIRE_INT8:
        # the two-level pad quantum already makes m a whole number of
        # quantization rows per node rank — the inner allreduce never re-pads
        legs += [
            LegBytes("reduce_scatter", "inter", cl.WIRE_BF16, m, 2 * m),
            LegBytes("all_gather", "inter", cl.WIRE_INT8, m, m,
                     m // cl.QUANT_BLOCK * _SCALE_BYTES),
        ]
    else:
        legs.append(LegBytes("allreduce", "inter", spec.wire_inter, m,
                             m * _float_bytes(spec.wire_inter)))
    legs.append(LegBytes("all_gather", "intra", spec.wire_intra, padded,
                         padded * isz))
    return tuple(legs)


@dataclasses.dataclass(frozen=True)
class BucketStats:
    """One bucket's row of the report."""

    index: int
    n_elems: int
    route: str               # planner.ALGO_FLAT | ALGO_HIER
    wire: str                # wire actually used (int8 falls back to bf16
                             # on non-fusable buckets — see reduce_chained)
    fusable: bool
    ef: bool
    axes: tuple
    legs: tuple              # LegBytes per phase; () when skip_reduce
    t_model: Optional[float] = None      # seconds, hw.Topology cost model
    t_measured: Optional[float] = None   # seconds, measure_bucket_times

    def _level_bytes(self, level: str) -> int:
        return sum(lg.total_bytes for lg in self.legs if lg.level == level)

    @property
    def intra_bytes(self) -> int:
        return self._level_bytes("intra")

    @property
    def inter_bytes(self) -> int:
        return self._level_bytes("inter")

    @property
    def total_bytes(self) -> int:
        return self.intra_bytes + self.inter_bytes

    @property
    def scale_bytes(self) -> int:
        return sum(lg.scale_bytes for lg in self.legs)

    @property
    def padded_elems(self) -> int:
        return max((lg.elems for lg in self.legs if lg.level != "inter"),
                   default=max((lg.elems for lg in self.legs), default=0))

    @property
    def pad_frac(self) -> float:
        if self.n_elems == 0 or not self.legs:
            return 0.0
        return self.padded_elems / self.n_elems - 1.0


def _bucket_stats(plan, bi: int, bucket, t_model, t_measured) -> BucketStats:
    route = plan.algos[bi]
    fusable = plan.fusable[bi]
    ef = plan.use_ef and fusable
    wire = plan.wire
    if plan.skip_reduce:
        legs = ()
    elif not fusable:
        # reduce_chained reduces non-fusable buckets per-leaf on a float
        # wire (the int8 flatten/scatter composition would reshard them) —
        # always the flat path, one unpadded message per leaf summed here
        route = planner_lib.ALGO_FLAT
        wire = cl.WIRE_BF16 if wire == cl.WIRE_INT8 else wire
        legs = (LegBytes("allreduce", "inter", wire, bucket.n_elems,
                         bucket.n_elems * _float_bytes(wire)),)
    elif route == planner_lib.ALGO_HIER:
        legs = _hier_legs(bucket.n_elems, plan.hier_spec, plan.n_local,
                          plan.n_node)
    else:
        legs = _flat_legs(bucket.n_elems, wire, plan.dp)
    return BucketStats(index=bi, n_elems=bucket.n_elems, route=route,
                       wire=wire, fusable=fusable, ef=ef,
                       axes=tuple(plan.axes_for(bi)), legs=legs,
                       t_model=t_model, t_measured=t_measured)


@dataclasses.dataclass(frozen=True)
class CommStats:
    """The per-bucket exchange report for one EnginePlan."""

    buckets: tuple           # BucketStats per bucket
    topo_name: str
    dp: int
    n_node: int
    n_local: int
    wire: str
    use_ef: bool
    quant_backend: str
    fused_quant: bool
    overlap: bool
    accum_steps: int

    @classmethod
    def from_plan(cls, plan, *, topo=None, measured=None) -> "CommStats":
        """Derive the report from an EnginePlan.

        `topo` (hw.Topology, a TOPOLOGIES name, or None) selects the cost
        model for the modeled column; None falls back to the plan's routing
        topology, then to hw.CLOUD_10G (the paper's baseline platform).
        `measured` is an optional per-bucket seconds sequence
        (measure_bucket_times).
        """
        if topo is None:
            topo = getattr(plan, "topo", None) or hw.CLOUD_10G
        if isinstance(topo, str):
            topo = hw.TOPOLOGIES[topo]
        # flat-only plans report n_node == 1; recover the node count the
        # cost model needs from dp over the topology's node width
        nodes = plan.n_node if plan.n_node > 1 else max(
            1, plan.dp // topo.local_size)
        t_model = planner_lib.bucket_allreduce_times(
            plan.buckets.buckets, plan.algos, nodes, topo, wire=plan.wire,
            ef=plan.use_ef, fused_quant=plan.fused_quant)
        if measured is None:
            measured = (None,) * plan.n_buckets
        rows = tuple(
            _bucket_stats(plan, bi, b, t_model[bi], measured[bi])
            for bi, b in enumerate(plan.buckets.buckets))
        return cls(buckets=rows, topo_name=topo.name, dp=plan.dp,
                   n_node=plan.n_node, n_local=plan.n_local, wire=plan.wire,
                   use_ef=plan.use_ef, quant_backend=plan.quant_backend,
                   fused_quant=plan.fused_quant, overlap=plan.overlap,
                   accum_steps=plan.accum_steps)

    # -- aggregates ---------------------------------------------------------

    @property
    def total_bytes(self) -> int:
        return sum(b.total_bytes for b in self.buckets)

    @property
    def intra_bytes(self) -> int:
        return sum(b.intra_bytes for b in self.buckets)

    @property
    def inter_bytes(self) -> int:
        return sum(b.inter_bytes for b in self.buckets)

    @property
    def t_model_total(self) -> float:
        return sum(b.t_model or 0.0 for b in self.buckets)

    @property
    def t_measured_total(self) -> Optional[float]:
        vals = [b.t_measured for b in self.buckets]
        if any(v is None for v in vals):
            return None
        return sum(vals)

    # -- rendering ----------------------------------------------------------

    def table(self) -> str:
        """The MLSL-style stats table (one row per bucket + totals)."""
        hdr = (f"CommStats: dp={self.dp} (node={self.n_node} x "
               f"local={self.n_local})  wire={self.wire}"
               f"{' +ef' if self.use_ef else ''}  "
               f"backend={self.quant_backend}"
               f"{' fused' if self.fused_quant else ' composed'}  "
               f"overlap={self.overlap} accum={self.accum_steps}  "
               f"model topo={self.topo_name}")
        cols = ("bkt", "elems", "route", "wire", "ef", "pad%", "intra_B",
                "inter_B", "scale_B", "total_B", "t_model_us", "t_meas_us")
        rows = [cols]
        for b in self.buckets:
            rows.append((
                str(b.index), str(b.n_elems), b.route, b.wire,
                "y" if b.ef else "-", f"{b.pad_frac * 100:.1f}",
                str(b.intra_bytes), str(b.inter_bytes), str(b.scale_bytes),
                str(b.total_bytes),
                f"{b.t_model * 1e6:.1f}" if b.t_model is not None else "-",
                f"{b.t_measured * 1e6:.1f}"
                if b.t_measured is not None else "-",
            ))
        tm = self.t_measured_total
        rows.append((
            "sum", str(sum(b.n_elems for b in self.buckets)), "", "", "", "",
            str(self.intra_bytes), str(self.inter_bytes),
            str(sum(b.scale_bytes for b in self.buckets)),
            str(self.total_bytes), f"{self.t_model_total * 1e6:.1f}",
            f"{tm * 1e6:.1f}" if tm is not None else "-"))
        widths = [max(len(r[c]) for r in rows) for c in range(len(cols))]
        lines = [hdr, ""]
        for i, r in enumerate(rows):
            lines.append("  ".join(v.rjust(w) for v, w in zip(r, widths)))
            if i == 0:
                lines.append("  ".join("-" * w for w in widths))
        return "\n".join(lines)

    def to_metrics(self) -> list:
        """Ledger entries (dicts matching benchmarks.common.Metric).

        Warn-only by construction: byte/count metrics are informational
        (``better=None``), time metrics are wall-clock-class
        (``stable=False``) — the diff gate never hard-fails on either.
        """
        out = []

        def info(name, value, unit=""):
            out.append({"name": name, "value": value, "unit": unit,
                        "better": None, "stable": True})

        def wallclock(name, value, unit="us"):
            out.append({"name": name, "value": value, "unit": unit,
                        "better": "lower", "stable": False})

        for b in self.buckets:
            pre = f"comm_stats/b{b.index:02d}"
            info(f"{pre}/elems", float(b.n_elems))
            info(f"{pre}/route", b.route)
            info(f"{pre}/wire", b.wire)
            info(f"{pre}/intra_B", float(b.intra_bytes), "B")
            info(f"{pre}/inter_B", float(b.inter_bytes), "B")
            info(f"{pre}/total_B", float(b.total_bytes), "B")
            if b.t_model is not None:
                wallclock(f"{pre}/t_model_us", b.t_model * 1e6)
            if b.t_measured is not None:
                wallclock(f"{pre}/t_measured_us", b.t_measured * 1e6)
        info("comm_stats/total/n_buckets", float(len(self.buckets)))
        info("comm_stats/total/topo", self.topo_name)
        info("comm_stats/total/intra_B", float(self.intra_bytes), "B")
        info("comm_stats/total/inter_B", float(self.inter_bytes), "B")
        info("comm_stats/total/total_B", float(self.total_bytes), "B")
        wallclock("comm_stats/total/t_model_us", self.t_model_total * 1e6)
        if self.t_measured_total is not None:
            wallclock("comm_stats/total/t_measured_us",
                      self.t_measured_total * 1e6)
        return out


# ---------------------------------------------------------------------------
# measured per-bucket service time (the engine's own data path, replayed)
# ---------------------------------------------------------------------------

class BucketTimer:
    """Compile-once, sample-many per-bucket replay of the engine data path.

    Each bucket's exchange runs standalone: the fused flat message (or
    per-leaf messages for non-fusable buckets) is reduced in its own jitted
    shard_map region over the plan's axes, exactly the branch
    ``reduce_chained`` takes for that bucket. Synthetic inputs — the wire
    traffic and kernel work are what is being measured, not the values.

    Building the jitted closures is the expensive part (tracing + compile),
    so it happens ONCE here; ``sample()`` is then cheap enough for the
    telemetry loop to call every N steps between training steps (the first
    ``sample`` still pays each bucket's compile — pass ``warmup >= 1`` on
    that call, as ``measure_bucket_times`` does, or discard it).
    """

    def __init__(self, engine, mesh, *, seed: int = 0):
        import jax
        import jax.numpy as jnp
        import numpy as np
        from jax.sharding import PartitionSpec as P

        from repro import compat

        p = engine.plan
        rng = np.random.default_rng(seed)
        bspec = p.data_axes if len(p.data_axes) > 1 else p.data_axes[0]
        manual = set(p.data_axes) | ({p.tp_axis} if p.tp_axis else set())
        residuals = engine.init_residuals()
        self.n_buckets = p.n_buckets
        self._cases = []          # (jitted_fn, args) or None for skip_reduce
        for bi, bucket in enumerate(p.buckets.buckets):
            if p.skip_reduce:
                self._cases.append(None)
                continue
            if p.fusable[bi]:
                flat = jnp.asarray(
                    rng.standard_normal(bucket.n_elems), jnp.float32)
                if engine.ef_applied(bi):
                    fn = compat.shard_map(
                        lambda f, r, _bi=bi:
                            engine._reduce_bucket(f, r, _bi)[0],
                        mesh=mesh, in_specs=(P(), P(bspec)), out_specs=P(),
                        axis_names=manual, check_vma=False)
                    args = (flat, residuals[bi])
                else:
                    fn = compat.shard_map(
                        lambda f, _bi=bi:
                            engine._reduce_bucket(f, None, _bi)[0],
                        mesh=mesh, in_specs=(P(),), out_specs=P(),
                        axis_names=manual, check_vma=False)
                    args = (flat,)
            else:
                vals = tuple(
                    jnp.asarray(rng.standard_normal(shape), jnp.float32)
                    for shape in bucket.shapes)
                wire = cl.WIRE_BF16 if p.wire == cl.WIRE_INT8 else p.wire
                axes = p.axes_for(bi)

                def leafwise(*vs, _axes=axes, _wire=wire):
                    return tuple(
                        cl.allreduce(v, _axes, wire=_wire, mean=True)
                        for v in vs)

                fn = compat.shard_map(
                    leafwise, mesh=mesh,
                    in_specs=tuple(P() for _ in vals),
                    out_specs=tuple(P() for _ in vals),
                    axis_names=manual, check_vma=False)
                args = vals
            self._cases.append((jax.jit(fn), args))

    def sample(self, *, iters: int = 1, warmup: int = 0) -> tuple:
        """Median wall seconds per bucket over `iters` timed replays."""
        import jax

        times = []
        for case in self._cases:
            if case is None:
                times.append(0.0)
                continue
            jf, args = case
            for _ in range(warmup):
                jax.block_until_ready(jf(*args))
            ts = []
            for _ in range(max(iters, 1)):
                t0 = time.perf_counter()
                jax.block_until_ready(jf(*args))
                ts.append(time.perf_counter() - t0)
            ts.sort()
            times.append(ts[len(ts) // 2])
        return tuple(times)


def measure_bucket_times(engine, mesh, *, iters: int = 3, warmup: int = 1,
                         seed: int = 0) -> tuple:
    """Median wall seconds per bucket of the engine's `_reduce_bucket` path
    (one-shot convenience over ``BucketTimer``)."""
    return BucketTimer(engine, mesh, seed=seed).sample(
        iters=iters, warmup=warmup)
