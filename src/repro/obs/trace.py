"""Chrome-trace-event timeline writer (Perfetto / chrome://tracing format).

One ``TraceWriter`` collects events from any mix of sources — host-side
``span()`` context managers around real work, measured per-bucket replay
durations, and the simulator's modeled span timeline
(``export_sim_spans``) — and writes a single JSON object file

    {"traceEvents": [...], "displayTimeUnit": "ms", ...}

loadable in https://ui.perfetto.dev. Tracks are labeled through process/
thread metadata events, so a measured mesh run (pid 0) and the modeled
iteration for the same config (pid 1) open side by side in one view — the
visual form of the repo's measured-vs-modeled story.

Timestamps are microseconds. All spans are emitted as complete ("X")
events, which Perfetto nests by containment, so writers never need to
balance begin/end pairs; ``validate_trace`` still checks "B"/"E" balance
for externally produced event lists.

This module deliberately imports nothing from ``repro`` (core modules may
import it without cycles); the only soft dependency is
``jax.profiler.TraceAnnotation``, picked up lazily inside ``span`` so the
host spans also land in an XLA profile when one is being taken.
"""

from __future__ import annotations

import contextlib
import json
import time
from typing import Iterable, Optional

# microseconds per second: Chrome trace ts/dur are in us
_US = 1e6


def _trace_annotation(name: str):
    """jax.profiler.TraceAnnotation when jax is importable, else a no-op —
    host spans then also show up in XLA profiles taken around the run."""
    try:
        import jax.profiler
        return jax.profiler.TraceAnnotation(name)
    except Exception:                                     # noqa: BLE001
        return contextlib.nullcontext()


class TraceWriter:
    """Collects Chrome trace events; `write()` emits the JSON object file."""

    def __init__(self, *, clock=time.perf_counter):
        self.events: list = []
        self._clock = clock
        self._t0 = clock()
        self._named_tracks: set = set()

    # -- clock --------------------------------------------------------------

    def now_us(self) -> float:
        """Wall-clock microseconds since this writer was created."""
        return (self._clock() - self._t0) * _US

    # -- raw events ---------------------------------------------------------

    def complete(self, name: str, ts_us: float, dur_us: float, *,
                 pid: int = 0, tid: int = 0, cat: str = "",
                 args: Optional[dict] = None) -> None:
        """One complete ("X") span event at an explicit time."""
        ev = {"name": name, "ph": "X", "ts": float(ts_us),
              "dur": max(float(dur_us), 0.0), "pid": pid, "tid": tid}
        if cat:
            ev["cat"] = cat
        if args:
            ev["args"] = args
        self.events.append(ev)

    def counter(self, name: str, ts_us: float, values: dict, *,
                pid: int = 0, tid: int = 0) -> None:
        """One counter ("C") sample: Perfetto renders each key of `values`
        as a series on the `name` counter track — e.g.
        ``counter("rates", ts, {"tokens_per_sec": 1.2e4})`` gives the rate
        timeline next to the span rows. Values must be numeric."""
        self.events.append({"name": name, "ph": "C", "ts": float(ts_us),
                            "pid": pid, "tid": tid,
                            "args": {k: float(v) for k, v in values.items()}})

    def instant(self, name: str, ts_us: float, *, pid: int = 0,
                tid: int = 0, cat: str = "") -> None:
        ev = {"name": name, "ph": "i", "ts": float(ts_us), "s": "t",
              "pid": pid, "tid": tid}
        if cat:
            ev["cat"] = cat
        self.events.append(ev)

    def name_process(self, pid: int, name: str) -> None:
        """Label a track group (Perfetto shows this as the process name)."""
        if ("p", pid) in self._named_tracks:
            return
        self._named_tracks.add(("p", pid))
        self.events.append({"name": "process_name", "ph": "M", "pid": pid,
                            "tid": 0, "args": {"name": name}})

    def name_thread(self, pid: int, tid: int, name: str) -> None:
        if ("t", pid, tid) in self._named_tracks:
            return
        self._named_tracks.add(("t", pid, tid))
        self.events.append({"name": "thread_name", "ph": "M", "pid": pid,
                            "tid": tid, "args": {"name": name}})

    # -- host-side spans ----------------------------------------------------

    @contextlib.contextmanager
    def span(self, name: str, *, pid: int = 0, tid: int = 0, cat: str = "",
             args: Optional[dict] = None):
        """Measure a host-side region: ``with writer.span("bucket3/inter")``.

        Nested spans nest in the viewer (containment of "X" events). The
        region is also wrapped in a ``jax.profiler.TraceAnnotation`` so it
        appears in XLA profiles taken around the same run.
        """
        t0 = self.now_us()
        with _trace_annotation(name):
            try:
                yield self
            finally:
                self.complete(name, t0, self.now_us() - t0, pid=pid,
                              tid=tid, cat=cat, args=args)

    # -- output -------------------------------------------------------------

    def to_json(self) -> dict:
        return {"traceEvents": list(self.events), "displayTimeUnit": "ms"}

    def write(self, path: str) -> str:
        obj = self.to_json()
        validate_trace(obj)
        with open(path, "w") as fh:
            json.dump(obj, fh, indent=1)
            fh.write("\n")
        return path


# --------------------------------------------------------------------------
# modeled-timeline export (repro.core.simulator span timelines)
# --------------------------------------------------------------------------

# one viewer row per span category, in a stable order
_CAT_TIDS = {"compute": 0, "comm": 1, "stall": 2}


def export_sim_spans(spans: Iterable, writer: TraceWriter, *, pid: int = 1,
                     track: str = "modeled", t0_us: float = 0.0) -> int:
    """Export a simulator span timeline into `writer`.

    `spans` is any iterable of objects with ``name`` / ``cat`` / ``start`` /
    ``end`` attributes and times in SECONDS (``simulator.SimSpan``:
    ``IterationStats.timeline`` / ``BucketScheduleStats.timeline`` with
    ``record_timeline=True``). Events land on `pid` with one thread row per
    category (compute / comm / stall), offset by `t0_us` so a modeled
    iteration can be laid next to a measured one. Returns the number of
    span events written.
    """
    writer.name_process(pid, track)
    n = 0
    for s in spans:
        tid = _CAT_TIDS.get(s.cat, len(_CAT_TIDS))
        writer.name_thread(pid, tid, s.cat)
        writer.complete(s.name, t0_us + s.start * _US,
                        (s.end - s.start) * _US, pid=pid, tid=tid, cat=s.cat)
        n += 1
    return n


# --------------------------------------------------------------------------
# loading / validation (tests and post-run assertions)
# --------------------------------------------------------------------------

def load_trace(path: str) -> dict:
    with open(path) as fh:
        obj = json.load(fh)
    validate_trace(obj)
    return obj


def validate_trace(obj) -> None:
    """Raise ValueError unless `obj` is a well-formed Chrome trace object:
    a JSON object whose ``traceEvents`` is a list of events with the
    required phase fields, non-negative "X" durations, numeric-valued "C"
    counter samples, and balanced "B"/"E" pairs per (pid, tid) track."""
    if not isinstance(obj, dict) or not isinstance(
            obj.get("traceEvents"), list):
        raise ValueError("trace must be an object with a traceEvents list")
    depth: dict = {}
    for ev in obj["traceEvents"]:
        if not isinstance(ev, dict) or "ph" not in ev or "name" not in ev:
            raise ValueError(f"malformed event: {ev!r}")
        ph = ev["ph"]
        if ph == "M":
            continue
        if "ts" not in ev or not isinstance(ev["ts"], (int, float)):
            raise ValueError(f"event missing numeric ts: {ev!r}")
        key = (ev.get("pid", 0), ev.get("tid", 0))
        if ph == "X":
            if not isinstance(ev.get("dur"), (int, float)) or ev["dur"] < 0:
                raise ValueError(f"X event needs dur >= 0: {ev!r}")
        elif ph == "C":
            args = ev.get("args")
            if not isinstance(args, dict) or not args or not all(
                    isinstance(v, (int, float)) and not isinstance(v, bool)
                    for v in args.values()):
                raise ValueError(
                    f"C event needs numeric args series: {ev!r}")
        elif ph == "B":
            depth[key] = depth.get(key, 0) + 1
        elif ph == "E":
            depth[key] = depth.get(key, 0) - 1
            if depth[key] < 0:
                raise ValueError(f"unbalanced E event on track {key}")
    bad = {k: v for k, v in depth.items() if v != 0}
    if bad:
        raise ValueError(f"unbalanced B/E spans on tracks {bad}")
