"""Streaming per-step telemetry: a schema-versioned JSONL event log.

MLSL exposes internal statistics so operators can watch a run *while it
executes* — the post-mortem CommStats table (repro.obs.stats) is not enough
when the question is "did step 4000 stop matching the model?". This module
is the streaming channel: one JSON object per line, flushed as written, so a
`tail -f` (or the online health monitor, repro.obs.detect) sees each step
as it lands and a killed run keeps everything it logged.

Record kinds (``SCHEMA_VERSION = 1``):

  * ``meta``          -- first line: schema version, creation time, free-form
    ``run`` info (config echo), the bucket-replay ``sample_every`` knob;
  * ``step``          -- one per training/decode step: ``step``,
    ``t_step_s`` (wall seconds), optional ``tok_s`` / ``loss`` /
    ``exposed_frac`` (the step meter's modeled exposed-comm share);
  * ``bucket_times``  -- sampled every N steps: per-bucket ``measured``
    reduce seconds (obs.stats.BucketTimer standalone replay) beside the
    ``modeled`` hw.Topology costs, the residual stream the detector watches;
  * ``alarm``         -- a typed health alarm (repro.obs.detect.Alarm):
    ``alarm`` {kind, factor, level, rank, detail} at ``step``.

Cheap enough to leave on: a step record is ~100 bytes of host-side JSON and
the per-bucket replay is *sampled* (default every 25 steps, 0 disables), so
the hot step path is never perturbed — the meter times only the step
function, and the replay runs between steps.

This module deliberately imports nothing from ``repro`` (same rule as
``obs.trace``): the simulator's labeled episode generator
(``repro.core.simulator.generate_episode``) emits plain dicts in this
schema without a dependency edge, and ``validate_telemetry`` is the single
contract both sides are tested against.
"""

from __future__ import annotations

import json
import time
from typing import Optional, Sequence

SCHEMA_VERSION = 1

KIND_META = "meta"
KIND_STEP = "step"
KIND_BUCKET_TIMES = "bucket_times"
KIND_ALARM = "alarm"

# default bucket-replay sampling period (steps); 0 disables the replay
DEFAULT_SAMPLE_EVERY = 25


class TelemetryWriter:
    """Appends schema-v1 JSONL records to `path`, one flushed line each.

    Usage::

        with TelemetryWriter(path, run_info={...}, sample_every=25) as tel:
            tel.step(step=s, t_step_s=dt, tok_s=..., loss=...)
            if tel.should_sample(s):
                tel.bucket_times(s, measured, modeled=modeled)
            tel.alarm(step=s, kind="straggler", factor=1.5)
    """

    def __init__(self, path: str, *, run_info: Optional[dict] = None,
                 sample_every: int = DEFAULT_SAMPLE_EVERY):
        self.path = path
        self.sample_every = int(sample_every)
        self.n_records = 0
        self._fh = open(path, "w")
        self._emit({"kind": KIND_META, "schema_version": SCHEMA_VERSION,
                    "created_unix": time.time(),
                    "sample_every": self.sample_every,
                    "run": dict(run_info or {})})

    # -- record emission -----------------------------------------------------

    def _emit(self, rec: dict) -> None:
        json.dump(rec, self._fh)
        self._fh.write("\n")
        self._fh.flush()          # tail -f / crash durability per record
        self.n_records += 1

    def step(self, *, step: int, t_step_s: float,
             tok_s: Optional[float] = None, loss: Optional[float] = None,
             exposed_frac: Optional[float] = None) -> None:
        rec = {"kind": KIND_STEP, "step": int(step),
               "t_step_s": float(t_step_s)}
        if tok_s is not None:
            rec["tok_s"] = float(tok_s)
        if loss is not None:
            rec["loss"] = float(loss)
        if exposed_frac is not None:
            rec["exposed_frac"] = float(exposed_frac)
        self._emit(rec)

    def bucket_times(self, step: int, measured: Optional[Sequence] = None,
                     *, modeled: Optional[Sequence] = None) -> None:
        """Sampled per-bucket reduce seconds; either column may be absent
        (the dry-run logs modeled-only, a replay without a cost model logs
        measured-only), but not both."""
        rec: dict = {"kind": KIND_BUCKET_TIMES, "step": int(step)}
        if measured is not None:
            rec["measured"] = [float(t) for t in measured]
        if modeled is not None:
            rec["modeled"] = [float(t) for t in modeled]
        if "measured" not in rec and "modeled" not in rec:
            raise ValueError("bucket_times needs measured and/or modeled")
        self._emit(rec)

    def alarm(self, *, step: int, kind: str, factor: float,
              level: str = "", rank: int = -1, detail: str = "") -> None:
        self._emit({"kind": KIND_ALARM, "step": int(step),
                    "alarm": {"kind": str(kind), "factor": float(factor),
                              "level": str(level), "rank": int(rank),
                              "detail": str(detail)}})

    # -- sampling ------------------------------------------------------------

    def should_sample(self, step: int) -> bool:
        """Is `step` a bucket-replay sampling step? Step 0 always samples
        (the detector's healthy baseline needs at least one warm-up sample);
        ``sample_every <= 0`` disables the replay entirely."""
        if self.sample_every <= 0:
            return False
        return step % self.sample_every == 0

    # -- lifecycle -----------------------------------------------------------

    def close(self) -> None:
        if self._fh is not None:
            self._fh.close()
            self._fh = None

    def __enter__(self) -> "TelemetryWriter":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


# ---------------------------------------------------------------------------
# loading / validation (the round-trip contract)
# ---------------------------------------------------------------------------

def load_telemetry(path: str) -> list:
    """Parse + validate a telemetry JSONL file into a list of record dicts."""
    events = []
    with open(path) as fh:
        for ln, line in enumerate(fh, 1):
            line = line.strip()
            if not line:
                continue
            try:
                events.append(json.loads(line))
            except json.JSONDecodeError as e:
                raise ValueError(f"{path}:{ln}: not JSON: {e}") from e
    validate_telemetry(events)
    return events


def _require_num(rec: dict, key: str) -> None:
    if not isinstance(rec.get(key), (int, float)) \
            or isinstance(rec.get(key), bool):
        raise ValueError(f"record needs numeric {key!r}: {rec!r}")


def validate_telemetry(events: Sequence) -> None:
    """Raise ValueError unless `events` is a well-formed schema-v1 stream:
    a leading ``meta`` record with a supported ``schema_version``, then
    ``step`` / ``bucket_times`` / ``alarm`` records with their required
    fields. Unknown kinds are rejected (a version bump must be explicit)."""
    if not events:
        raise ValueError("empty telemetry stream (missing meta record)")
    head = events[0]
    if not isinstance(head, dict) or head.get("kind") != KIND_META:
        raise ValueError(f"first record must be kind=meta: {head!r}")
    ver = head.get("schema_version")
    if not isinstance(ver, int) or ver < 1 or ver > SCHEMA_VERSION:
        raise ValueError(f"unsupported schema_version {ver!r} "
                         f"(supported: 1..{SCHEMA_VERSION})")
    for rec in events[1:]:
        if not isinstance(rec, dict):
            raise ValueError(f"record must be an object: {rec!r}")
        kind = rec.get("kind")
        if kind == KIND_STEP:
            _require_num(rec, "step")
            _require_num(rec, "t_step_s")
        elif kind == KIND_BUCKET_TIMES:
            _require_num(rec, "step")
            cols = [c for c in ("measured", "modeled") if c in rec]
            if not cols:
                raise ValueError(
                    f"bucket_times needs measured and/or modeled: {rec!r}")
            for col in cols:
                vals = rec[col]
                if not isinstance(vals, list) or not all(
                        isinstance(t, (int, float)) and t >= 0
                        for t in vals):
                    raise ValueError(
                        f"bucket_times {col} must be a list of non-negative "
                        f"numbers: {rec!r}")
        elif kind == KIND_ALARM:
            _require_num(rec, "step")
            al = rec.get("alarm")
            if not isinstance(al, dict) or not isinstance(
                    al.get("kind"), str):
                raise ValueError(f"alarm record needs alarm.kind: {rec!r}")
            _require_num(al, "factor")
        elif kind == KIND_META:
            raise ValueError("duplicate meta record (one stream, one meta)")
        else:
            raise ValueError(f"unknown record kind {kind!r}: {rec!r}")
