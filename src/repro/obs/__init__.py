"""Observability: MLSL-style comm stats, Chrome-trace timelines, step meter.

MLSL's proof points (paper §4) are per-message statistics — bytes, algorithm,
exposed vs overlapped time — that only the library owning the exchange can
produce. This subpackage is that accounting layer for the reproduction:

  repro.obs.trace  -- Chrome-trace-event (Perfetto-compatible) writer with
                      host-side span helpers and an exporter for the
                      simulator's modeled span timeline, so a measured mesh
                      run and a modeled iteration open side by side in one
                      Perfetto view.
  repro.obs.stats  -- CommStats: the per-bucket wire-byte / route / modeled-
                      vs-measured-time report derived from an EnginePlan
                      (surfaced as EnginePlan.describe() / CommEngine.stats()
                      and serialized into the perf-ledger schema).
  repro.obs.meter  -- StepMeter: step-time EMA, tokens/sec, loss/grad-norm
                      tracking for the train/serve drivers (--stats).
  repro.obs.telemetry -- streaming schema-versioned JSONL event log (step
                      time, sampled per-bucket reduce times, tok/s, alarms)
                      cheap enough to leave on for a whole run.
  repro.obs.detect -- HealthMonitor: online measured-vs-modeled residual
                      tracking with EWMA/robust-z detectors classifying
                      sustained drift into typed alarms (straggler /
                      link_degraded / step_time_drift), each carrying a
                      Topology.degrade-ready factor estimate and a
                      "would re-route K buckets" reaction hook.

Layering: trace.py and telemetry.py depend on nothing in repro (core modules
may emit their schemas without a cycle); stats.py and detect.py sit ABOVE
repro.core (core reaches them only through lazy imports).
"""
