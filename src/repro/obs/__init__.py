"""Observability: MLSL-style comm stats, Chrome-trace timelines, step meter.

MLSL's proof points (paper §4) are per-message statistics — bytes, algorithm,
exposed vs overlapped time — that only the library owning the exchange can
produce. This subpackage is that accounting layer for the reproduction:

  repro.obs.trace  -- Chrome-trace-event (Perfetto-compatible) writer with
                      host-side span helpers and an exporter for the
                      simulator's modeled span timeline, so a measured mesh
                      run and a modeled iteration open side by side in one
                      Perfetto view.
  repro.obs.stats  -- CommStats: the per-bucket wire-byte / route / modeled-
                      vs-measured-time report derived from an EnginePlan
                      (surfaced as EnginePlan.describe() / CommEngine.stats()
                      and serialized into the perf-ledger schema).
  repro.obs.meter  -- StepMeter: step-time EMA, tokens/sec, loss/grad-norm
                      tracking for the train/serve drivers (--stats).

Layering: trace.py depends on nothing in repro (core modules may import it);
stats.py sits ABOVE repro.core (core reaches it only through lazy imports).
"""
