"""StepMeter: step-time / throughput / loss accounting for the drivers.

A tiny host-side meter the train and serve loops feed once per step
(``--stats``): bias-corrected EMA of step wall time, tokens/sec, running
loss / grad-norm, and — when given a modeled exposed-comm estimate for the
config — the share of the measured step the model attributes to exposed
communication (Keuper & Pfreundt's compute-vs-comm decomposition as a
single per-step number).

Pure host code, no jax dependency; works on floats the caller has already
pulled off the device (do not pass live DeviceArrays from inside a step —
that forces a sync the caller did not ask for).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Optional


@dataclasses.dataclass
class StepMeter:
    """EMA step meter. Call ``start()`` before each step's dispatch and
    ``update(...)`` after blocking on its result (or pass ``dt`` directly)."""

    ema_decay: float = 0.9
    tokens_per_step: float = 0.0          # constant per-step token count
    exposed_comm_model: Optional[float] = None   # modeled exposed s/step

    steps: int = 0
    _ema: float = 0.0                      # biased EMA accumulator
    _t_start: Optional[float] = None
    _t_total: float = 0.0
    _tokens_total: float = 0.0
    last_dt: float = 0.0
    last_loss: Optional[float] = None
    last_grad_norm: Optional[float] = None

    def start(self) -> None:
        self._t_start = time.perf_counter()

    def update(self, *, dt: Optional[float] = None,
               loss: Optional[float] = None,
               grad_norm: Optional[float] = None,
               tokens: Optional[float] = None) -> None:
        """Record one finished step; `dt` defaults to time since `start()`."""
        if dt is None:
            if self._t_start is None:
                raise ValueError("update() without dt needs a prior start()")
            dt = time.perf_counter() - self._t_start
        self._t_start = None
        self.steps += 1
        self.last_dt = dt
        self._t_total += dt
        self._tokens_total += (tokens if tokens is not None
                               else self.tokens_per_step)
        self._ema = self.ema_decay * self._ema + (1 - self.ema_decay) * dt
        if loss is not None:
            self.last_loss = float(loss)
        if grad_norm is not None:
            self.last_grad_norm = float(grad_norm)

    # -- derived ------------------------------------------------------------

    @property
    def step_time(self) -> float:
        """Bias-corrected EMA of step wall time (seconds)."""
        if self.steps == 0:
            return 0.0
        return self._ema / (1 - self.ema_decay ** self.steps)

    @property
    def tokens_per_sec(self) -> float:
        return self._tokens_total / self._t_total if self._t_total else 0.0

    @property
    def exposed_comm_frac(self) -> Optional[float]:
        """Modeled exposed-comm share of the measured step (None without a
        model estimate; capped at 1 — a faster-than-modeled step means the
        model overestimates, not >100% communication)."""
        if self.exposed_comm_model is None or self.step_time <= 0:
            return None
        return min(self.exposed_comm_model / self.step_time, 1.0)

    def summary(self) -> str:
        """One status line for the driver's log."""
        parts = [f"step {self.steps}",
                 f"step_time {self.step_time * 1e3:.1f}ms"]
        if self._tokens_total:
            parts.append(f"tok/s {self.tokens_per_sec:.0f}")
        if self.exposed_comm_frac is not None:
            parts.append(f"exposed_comm ~{self.exposed_comm_frac:.0%}")
        if self.last_loss is not None:
            parts.append(f"loss {self.last_loss:.4f}")
        if self.last_grad_norm is not None:
            parts.append(f"gnorm {self.last_grad_norm:.3f}")
        return "  ".join(parts)

    def to_metrics(self, prefix: str = "meter") -> list:
        """Ledger entries (benchmarks.common.Metric dicts) — all wall-clock,
        hence unstable/warn-only."""
        out = [{"name": f"{prefix}/step_time_us",
                "value": self.step_time * 1e6, "unit": "us",
                "better": "lower", "stable": False}]
        if self._tokens_total:
            out.append({"name": f"{prefix}/tokens_per_sec",
                        "value": self.tokens_per_sec, "unit": "",
                        "better": "higher", "stable": False})
        if self.exposed_comm_frac is not None:
            out.append({"name": f"{prefix}/exposed_comm_frac",
                        "value": self.exposed_comm_frac, "unit": "",
                        "better": "lower", "stable": False})
        return out
