"""Checkpointing: flat-path .npz payload + JSON manifest, restore with
optional resharding onto a mesh.

Single-host implementation (this container); the format is deliberately
host-count-agnostic: every leaf is stored fully replicated under its tree
path, and `restore` re-applies whatever shardings the planner dictates, so a
checkpoint taken at one mesh shape restores onto another (the standard
reshard-on-restore pattern)."""

from __future__ import annotations

import json
import os
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def save(directory: str, tree: Any, *, step: int | None = None) -> str:
    os.makedirs(directory, exist_ok=True)
    flat = jax.tree_util.tree_leaves_with_path(tree)
    payload = {}
    manifest = {"paths": [], "step": step}
    for path, leaf in flat:
        key = _path_str(path)
        manifest["paths"].append(key)
        arr = np.asarray(jax.device_get(leaf))
        if arr.dtype == jnp.bfloat16:
            payload[key] = arr.view(np.uint16)
            manifest.setdefault("bf16", []).append(key)
        else:
            payload[key] = arr
    np.savez(os.path.join(directory, "payload.npz"), **payload)
    with open(os.path.join(directory, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    return directory


def restore(directory: str, like: Any, *, shardings: Optional[Any] = None) -> Any:
    """Restore into the structure of `like` (a pytree of arrays or
    ShapeDtypeStructs). `shardings`: matching tree of NamedShardings."""
    with open(os.path.join(directory, "manifest.json")) as f:
        manifest = json.load(f)
    bf16 = set(manifest.get("bf16", []))
    payload = np.load(os.path.join(directory, "payload.npz"))
    flat, treedef = jax.tree_util.tree_flatten_with_path(like)
    sh_leaves = (jax.tree_util.tree_leaves(shardings) if shardings is not None
                 else [None] * len(flat))
    out = []
    for (path, leaf), sh in zip(flat, sh_leaves):
        key = _path_str(path)
        arr = payload[key]
        if key in bf16:
            arr = arr.view(jnp.bfloat16)
        assert tuple(arr.shape) == tuple(leaf.shape), (key, arr.shape,
                                                       leaf.shape)
        out.append(jax.device_put(arr, sh) if sh is not None
                   else jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out)


def latest_step(directory: str) -> int | None:
    try:
        with open(os.path.join(directory, "manifest.json")) as f:
            return json.load(f).get("step")
    except FileNotFoundError:
        return None
