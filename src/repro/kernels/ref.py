"""Pure-jnp oracles for the Pallas kernels (used by tests and as the CPU
fallback's ground truth). Signatures mirror repro.kernels.quant8."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_blocks(x2d: jax.Array):
    x = x2d.astype(jnp.float32)
    amax = jnp.max(jnp.abs(x), axis=1)
    scale = amax / 127.0
    safe = jnp.where(scale > 0.0, scale, 1.0)
    q = jnp.clip(jnp.round(x / safe[:, None]), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize_blocks(q2d: jax.Array, scales: jax.Array, *,
                      out_dtype=jnp.float32):
    return (q2d.astype(jnp.float32)
            * scales.astype(jnp.float32)[:, None]).astype(out_dtype)


def dequantize_accumulate_blocks(q2d: jax.Array, scales: jax.Array,
                                 acc: jax.Array, *, out_dtype=jnp.float32):
    deq = q2d.astype(jnp.float32) * scales.astype(jnp.float32)[:, None]
    return (acc.astype(jnp.float32) + deq).astype(out_dtype)


def quantize_ef_blocks(x2d: jax.Array, res2d: jax.Array):
    """Composed oracle for the fused error-feedback quantize.

    Same expression graph as quant8._quantize_ef_kernel: the residual update
    is ``y + q * (-s)`` via dequantize_accumulate_blocks, which is bitwise
    ``y - q * s`` (IEEE negation is exact), so the fused kernel and this
    composition agree bit-for-bit at fp32."""
    y = x2d.astype(jnp.float32) + res2d.astype(jnp.float32)
    q, scale = quantize_blocks(y)
    new_residual = dequantize_accumulate_blocks(q, -scale, y)
    return q, scale, new_residual


def flash_attention(q, k, v, *, causal=True, window=None):
    """Oracle for kernels.flashattn: plain masked softmax attention.

    q/k/v (B, H, S, D)."""
    import math
    B, H, Sq, D = q.shape
    Sk = k.shape[2]
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(D)
    q_pos = jnp.arange(Sq)[:, None]
    k_pos = jnp.arange(Sk)[None, :]
    valid = jnp.ones((Sq, Sk), bool)
    if causal:
        valid &= k_pos <= q_pos
    if window is not None:
        valid &= k_pos > q_pos - window
    s = jnp.where(valid[None, None], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", w, v.astype(jnp.float32)).astype(
        q.dtype)
