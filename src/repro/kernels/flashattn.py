"""Pallas TPU flash-attention (forward) kernel.

The online-softmax attention used by the 32k-token prefill shapes
(models/attention.py `chunked_sdpa` is the pure-jnp/XLA formulation; this is
the hand-tiled TPU kernel for the same math). Tiling:

  grid = (B, H, Sq/BQ, Sk/BK), with the KV axis innermost ("arbitrary"
  semantics): each (b, h, iq) output block is revisited across ik steps while
  the running max / denominator / weighted accumulator live in VMEM scratch.
  Q/K/V tiles are VMEM blocks of (BQ, D) / (BK, D); D is the full head dim
  (MXU-aligned: 64/128 in the assigned archs).

Causal and sliding-window masking are applied per tile from absolute
positions. Fully-masked tiles still execute (static grid) — block-sparse
skipping is listed as future work in EXPERIMENTS.md. Validated against
ref.flash_attention in interpret mode (tests/test_kernels_flashattn.py).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BQ = 128
DEFAULT_BK = 128
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, window, bq: int, bk: int,
                  sk: int):
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)              # (BQ, D)
    k = k_ref[0, 0].astype(jnp.float32)              # (BK, D)
    v = v_ref[0, 0].astype(jnp.float32)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale

    iq = pl.program_id(2)
    q_pos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    valid = k_pos < sk
    if causal:
        valid &= k_pos <= q_pos
    if window is not None:
        valid &= k_pos > q_pos - window
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_scr[...]                               # (BQ, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1, keepdims=True)
    m_scr[...] = m_new
    acc_scr[...] = acc_scr[...] * corr + jnp.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32)

    @pl.when(ik == nk - 1)
    def _finish():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "bq", "bk",
                                             "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int | None = None,
                    bq: int = DEFAULT_BQ, bk: int = DEFAULT_BK,
                    interpret: bool = False) -> jax.Array:
    """q (B, H, Sq, D); k/v (B, H, Sk, D) (heads already repeated)."""
    B, H, Sq, D = q.shape
    Sk = k.shape[2]
    bq = min(bq, Sq)
    bk = min(bk, Sk)
    pad_q = (-Sq) % bq
    pad_k = (-Sk) % bk
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    nq = q.shape[2] // bq
    nk = k.shape[2] // bk
    scale = 1.0 / math.sqrt(D)

    kernel = functools.partial(_flash_kernel, scale=scale, causal=causal,
                               window=window, bq=bq, bk=bk, sk=Sk)
    out = pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, iq, ik: (b, h, ik, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, iq, ik: (b, h, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D), lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),     # running max
            pltpu.VMEM((bq, 1), jnp.float32),     # running denominator
            pltpu.VMEM((bq, D), jnp.float32),     # weighted accumulator
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :Sq, :]
