"""Pallas TPU kernels for block-wise symmetric int8 quantization.

This is the performance-critical data-path operation of the paper's
low-precision communication feature (C6): gradients are quantized to int8
with one fp32 scale per block before hitting the wire, and dequantized (and
optionally accumulated) after the collective.

TPU mapping: the gradient bucket is viewed as (n_blocks, block) with
block a multiple of 128 (lane width) so each VMEM tile is MXU/VPU aligned.
The grid walks row-tiles of TILE_ROWS blocks; abs-max reduction, scaling and
rounding all happen inside VMEM, one HBM round-trip total -- on CPU the same
kernels run under interpret=True and are validated against ref.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Lane width on TPU is 128; sublane granularity for fp32 is 8.
LANE = 128
DEFAULT_BLOCK = 512          # elements per quantization block (multiple of 128)
TILE_ROWS = 8                # quantization blocks handled per grid step


def _quantize_kernel(x_ref, q_ref, s_ref):
    """One tile: (TILE_ROWS, block) f32 -> int8 + per-row scale."""
    x = x_ref[...].astype(jnp.float32)
    amax = jnp.max(jnp.abs(x), axis=1)                   # (rows,)
    scale = amax / 127.0
    safe = jnp.where(scale > 0.0, scale, 1.0)
    q = jnp.clip(jnp.round(x / safe[:, None]), -127, 127)
    q_ref[...] = q.astype(jnp.int8)
    s_ref[...] = scale.astype(jnp.float32)


def _dequantize_kernel(q_ref, s_ref, o_ref, *, out_dtype):
    q = q_ref[...].astype(jnp.float32)
    s = s_ref[...].astype(jnp.float32)
    o_ref[...] = (q * s[:, None]).astype(out_dtype)


def _dequant_accum_kernel(q_ref, s_ref, acc_ref, o_ref, *, out_dtype):
    """Fused dequantize + accumulate: o = acc + q * s (error-feedback path)."""
    q = q_ref[...].astype(jnp.float32)
    s = s_ref[...].astype(jnp.float32)
    acc = acc_ref[...].astype(jnp.float32)
    o_ref[...] = (acc + q * s[:, None]).astype(out_dtype)


def _grid(n_blocks: int) -> tuple:
    assert n_blocks % TILE_ROWS == 0, (n_blocks, TILE_ROWS)
    return (n_blocks // TILE_ROWS,)


@functools.partial(jax.jit, static_argnames=("interpret",))
def quantize_blocks(x2d: jax.Array, *, interpret: bool = False):
    """x2d: (n_blocks, block) float -> (int8 (n_blocks, block), f32 (n_blocks,)).

    n_blocks must be a multiple of TILE_ROWS and block a multiple of LANE
    (callers pad; see repro.kernels.ops).
    """
    n_blocks, block = x2d.shape
    assert block % LANE == 0, block
    return pl.pallas_call(
        _quantize_kernel,
        grid=_grid(n_blocks),
        in_specs=[pl.BlockSpec((TILE_ROWS, block), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((TILE_ROWS, block), lambda i: (i, 0)),
            pl.BlockSpec((TILE_ROWS,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_blocks, block), jnp.int8),
            jax.ShapeDtypeStruct((n_blocks,), jnp.float32),
        ],
        interpret=interpret,
    )(x2d)


@functools.partial(jax.jit, static_argnames=("out_dtype", "interpret"))
def dequantize_blocks(q2d: jax.Array, scales: jax.Array, *,
                      out_dtype=jnp.float32, interpret: bool = False):
    n_blocks, block = q2d.shape
    assert block % LANE == 0, block
    return pl.pallas_call(
        functools.partial(_dequantize_kernel, out_dtype=out_dtype),
        grid=_grid(n_blocks),
        in_specs=[
            pl.BlockSpec((TILE_ROWS, block), lambda i: (i, 0)),
            pl.BlockSpec((TILE_ROWS,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((TILE_ROWS, block), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_blocks, block), out_dtype),
        interpret=interpret,
    )(q2d, scales)


@functools.partial(jax.jit, static_argnames=("out_dtype", "interpret"))
def dequantize_accumulate_blocks(q2d: jax.Array, scales: jax.Array,
                                 acc: jax.Array, *, out_dtype=jnp.float32,
                                 interpret: bool = False):
    n_blocks, block = q2d.shape
    assert block % LANE == 0, block
    return pl.pallas_call(
        functools.partial(_dequant_accum_kernel, out_dtype=out_dtype),
        grid=_grid(n_blocks),
        in_specs=[
            pl.BlockSpec((TILE_ROWS, block), lambda i: (i, 0)),
            pl.BlockSpec((TILE_ROWS,), lambda i: (i,)),
            pl.BlockSpec((TILE_ROWS, block), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((TILE_ROWS, block), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_blocks, block), out_dtype),
        interpret=interpret,
    )(q2d, scales, acc)
