"""Pallas TPU kernels for block-wise symmetric int8 quantization.

This is the performance-critical data-path operation of the paper's
low-precision communication feature (C6): gradients are quantized to int8
with one fp32 scale per block before hitting the wire, and dequantized (and
optionally accumulated) after the collective.

TPU mapping: the gradient bucket is viewed as (n_blocks, block) with
block a multiple of 128 (lane width) so each VMEM tile is MXU/VPU aligned.
The grid walks row-tiles of TILE_ROWS blocks; abs-max reduction, scaling and
rounding all happen inside VMEM, one HBM round-trip total -- on CPU the same
kernels run under interpret=True and are validated against ref.py.

Fused wire hot path (one HBM read + one write of the gradient per leg):

  * ``quantize_cast_blocks``   -- bf16/f32 input cast in-tile, so the wire
    cast never materializes an intermediate copy in HBM;
  * ``quantize_ef_blocks``     -- x + residual -> (q, scales, new_residual)
    in a single VMEM pass (the error-feedback add, the quantization, and the
    residual update that used to be 3-4 separate passes);
  * ``dequantize_accumulate_blocks`` -- acc + q * s on the gather side, so
    microbatch gradient accumulation consumes the int8 message directly.

A bf16 tile rides the f32 (TILE_ROWS x block) tiling quantum: block is a
multiple of 128 lanes and sub-native sublane tiles are masked by Mosaic, so
one grid layout serves every input dtype and callers pad once.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Lane width on TPU is 128; sublane granularity for fp32 is 8.
LANE = 128
DEFAULT_BLOCK = 512          # elements per quantization block (multiple of 128)
TILE_ROWS = 8                # quantization blocks handled per grid step


def _quantize_kernel(x_ref, q_ref, s_ref):
    """One tile: (TILE_ROWS, block) float -> int8 + per-row scale.

    The input cast to f32 happens on the VMEM tile, so a bf16 wire buffer is
    consumed directly (no materialized f32 copy in HBM)."""
    x = x_ref[...].astype(jnp.float32)
    amax = jnp.max(jnp.abs(x), axis=1)                   # (rows,)
    scale = amax / 127.0
    safe = jnp.where(scale > 0.0, scale, 1.0)
    q = jnp.clip(jnp.round(x / safe[:, None]), -127, 127)
    q_ref[...] = q.astype(jnp.int8)
    s_ref[...] = scale.astype(jnp.float32)


def _quantize_ef_kernel(x_ref, r_ref, q_ref, s_ref, nr_ref):
    """Fused error-feedback quantize, one tile in VMEM:

        y = f32(x) + residual
        q, scale = blockwise int8 quantization of y
        new_residual = y - q * scale

    What used to be the add / quantize / dequantize-to-get-the-error triple
    (3-4 HBM round-trips in collectives.allreduce_ef) reads x and residual
    once and writes q, scale, new_residual once."""
    y = x_ref[...].astype(jnp.float32) + r_ref[...].astype(jnp.float32)
    amax = jnp.max(jnp.abs(y), axis=1)
    scale = amax / 127.0
    safe = jnp.where(scale > 0.0, scale, 1.0)
    q = jnp.clip(jnp.round(y / safe[:, None]), -127, 127)
    q_ref[...] = q.astype(jnp.int8)
    s_ref[...] = scale.astype(jnp.float32)
    nr_ref[...] = y - q * scale[:, None]


def _dequantize_kernel(q_ref, s_ref, o_ref, *, out_dtype):
    q = q_ref[...].astype(jnp.float32)
    s = s_ref[...].astype(jnp.float32)
    o_ref[...] = (q * s[:, None]).astype(out_dtype)


def _dequant_accum_kernel(q_ref, s_ref, acc_ref, o_ref, *, out_dtype):
    """Fused dequantize + accumulate: o = acc + q * s (error-feedback path)."""
    q = q_ref[...].astype(jnp.float32)
    s = s_ref[...].astype(jnp.float32)
    acc = acc_ref[...].astype(jnp.float32)
    o_ref[...] = (acc + q * s[:, None]).astype(out_dtype)


def _grid(n_blocks: int) -> tuple:
    # ValueError (not assert): the message survives `python -O` and names the
    # offending shape plus the tiling quantum the caller must pad to.
    if n_blocks % TILE_ROWS != 0:
        raise ValueError(
            f"n_blocks={n_blocks} is not a multiple of the row-tile quantum "
            f"TILE_ROWS={TILE_ROWS}; pad the flat buffer to a multiple of "
            f"TILE_ROWS * block elements (see repro.kernels.ops._to_blocks)")
    return (n_blocks // TILE_ROWS,)


def _check_block(shape: tuple) -> None:
    n_blocks, block = shape
    if block % LANE != 0:
        raise ValueError(
            f"block size {block} of a ({n_blocks}, {block}) buffer is not a "
            f"multiple of the TPU lane width LANE={LANE}; quantization "
            f"blocks must tile the 128-lane vector registers")


@functools.partial(jax.jit, static_argnames=("interpret",))
def quantize_blocks(x2d: jax.Array, *, interpret: bool = False):
    """x2d: (n_blocks, block) f32 -> (int8 (n_blocks, block), f32 (n_blocks,)).

    n_blocks must be a multiple of TILE_ROWS and block a multiple of LANE
    (callers pad; see repro.kernels.ops).
    """
    n_blocks, block = x2d.shape
    _check_block(x2d.shape)
    return pl.pallas_call(
        _quantize_kernel,
        grid=_grid(n_blocks),
        in_specs=[pl.BlockSpec((TILE_ROWS, block), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((TILE_ROWS, block), lambda i: (i, 0)),
            pl.BlockSpec((TILE_ROWS,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_blocks, block), jnp.int8),
            jax.ShapeDtypeStruct((n_blocks,), jnp.float32),
        ],
        interpret=interpret,
    )(x2d)


# The wire cast is folded into the quantize tile (`_quantize_kernel` casts on
# the VMEM block), so any float input quantizes without a materialized f32
# copy; the separate name documents the contract for bf16 wire buffers.
quantize_cast_blocks = quantize_blocks


@functools.partial(jax.jit, static_argnames=("interpret",))
def quantize_ef_blocks(x2d: jax.Array, res2d: jax.Array, *,
                       interpret: bool = False):
    """Fused error-feedback quantize (one HBM round-trip).

    x2d: (n_blocks, block) float (bf16 wire buffers welcome -- cast in-tile);
    res2d: (n_blocks, block) f32 residual carried from the previous step.
    Returns (q int8, scales f32 (n_blocks,), new_residual f32) where
    q/scales quantize ``x + res`` and ``new_residual = x + res - q * s``.
    """
    n_blocks, block = x2d.shape
    _check_block(x2d.shape)
    if res2d.shape != x2d.shape:
        raise ValueError(
            f"residual shape {res2d.shape} must match the blocked input "
            f"shape {x2d.shape}")
    return pl.pallas_call(
        _quantize_ef_kernel,
        grid=_grid(n_blocks),
        in_specs=[
            pl.BlockSpec((TILE_ROWS, block), lambda i: (i, 0)),
            pl.BlockSpec((TILE_ROWS, block), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((TILE_ROWS, block), lambda i: (i, 0)),
            pl.BlockSpec((TILE_ROWS,), lambda i: (i,)),
            pl.BlockSpec((TILE_ROWS, block), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_blocks, block), jnp.int8),
            jax.ShapeDtypeStruct((n_blocks,), jnp.float32),
            jax.ShapeDtypeStruct((n_blocks, block), jnp.float32),
        ],
        interpret=interpret,
    )(x2d, res2d)


@functools.partial(jax.jit, static_argnames=("out_dtype", "interpret"))
def dequantize_blocks(q2d: jax.Array, scales: jax.Array, *,
                      out_dtype=jnp.float32, interpret: bool = False):
    n_blocks, block = q2d.shape
    _check_block(q2d.shape)
    return pl.pallas_call(
        functools.partial(_dequantize_kernel, out_dtype=out_dtype),
        grid=_grid(n_blocks),
        in_specs=[
            pl.BlockSpec((TILE_ROWS, block), lambda i: (i, 0)),
            pl.BlockSpec((TILE_ROWS,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((TILE_ROWS, block), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_blocks, block), out_dtype),
        interpret=interpret,
    )(q2d, scales)


@functools.partial(jax.jit, static_argnames=("out_dtype", "interpret"))
def dequantize_accumulate_blocks(q2d: jax.Array, scales: jax.Array,
                                 acc: jax.Array, *, out_dtype=jnp.float32,
                                 interpret: bool = False):
    n_blocks, block = q2d.shape
    _check_block(q2d.shape)
    return pl.pallas_call(
        functools.partial(_dequant_accum_kernel, out_dtype=out_dtype),
        grid=_grid(n_blocks),
        in_specs=[
            pl.BlockSpec((TILE_ROWS, block), lambda i: (i, 0)),
            pl.BlockSpec((TILE_ROWS,), lambda i: (i,)),
            pl.BlockSpec((TILE_ROWS, block), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((TILE_ROWS, block), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_blocks, block), out_dtype),
        interpret=interpret,
    )(q2d, scales, acc)
