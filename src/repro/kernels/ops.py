"""Public, shape-polymorphic wrappers over the quantization kernels.

`quantize`/`dequantize` accept arbitrary-shaped tensors: they flatten, pad to
the kernel's (TILE_ROWS x block) tiling, and restore shape on the way back.

Backend selection:
  * "pallas"  -- pl.pallas_call (compiled on TPU; interpret=True elsewhere).
  * "jnp"     -- the pure-jnp oracle (identical math; used inside GSPMD-
                 partitioned regions and as the CPU default).
  * "auto"    -- pallas on TPU, jnp otherwise.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.kernels import quant8, ref


@dataclasses.dataclass(frozen=True)
class QuantMeta:
    """Static metadata needed to invert `quantize`."""

    shape: tuple
    dtype: Any
    n: int                # true element count before padding
    block: int


def _backend(backend: str) -> str:
    if backend == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "jnp"
    return backend


def _to_blocks(x: jax.Array, block: int):
    """Flatten + zero-pad to (n_blocks, block) with n_blocks % TILE_ROWS == 0."""
    flat = x.reshape(-1).astype(jnp.float32)
    n = flat.shape[0]
    row_quantum = block * quant8.TILE_ROWS
    padded = ((n + row_quantum - 1) // row_quantum) * row_quantum
    flat = jnp.pad(flat, (0, padded - n))
    return flat.reshape(-1, block), n


def quantize(x: jax.Array, *, block: int = quant8.DEFAULT_BLOCK,
             backend: str = "auto"):
    """x (any shape) -> (q int8 (n_blocks, block), scales f32, QuantMeta)."""
    be = _backend(backend)
    x2d, n = _to_blocks(x, block)
    if be == "pallas":
        interpret = jax.default_backend() != "tpu"
        q, s = quant8.quantize_blocks(x2d, interpret=interpret)
    else:
        q, s = ref.quantize_blocks(x2d)
    meta = QuantMeta(shape=tuple(x.shape), dtype=x.dtype, n=n, block=block)
    return q, s, meta


def dequantize(q: jax.Array, scales: jax.Array, meta: QuantMeta, *,
               backend: str = "auto") -> jax.Array:
    be = _backend(backend)
    if be == "pallas":
        interpret = jax.default_backend() != "tpu"
        x2d = quant8.dequantize_blocks(q, scales, out_dtype=jnp.float32,
                                       interpret=interpret)
    else:
        x2d = ref.dequantize_blocks(q, scales, out_dtype=jnp.float32)
    flat = x2d.reshape(-1)[: meta.n]
    return flat.reshape(meta.shape).astype(meta.dtype)


def dequantize_accumulate(q: jax.Array, scales: jax.Array, acc: jax.Array,
                          meta: QuantMeta, *,
                          backend: str = "auto") -> jax.Array:
    """acc (same logical shape as the original tensor) + dequant(q)."""
    be = _backend(backend)
    acc2d, _ = _to_blocks(acc, meta.block)
    if be == "pallas":
        interpret = jax.default_backend() != "tpu"
        x2d = quant8.dequantize_accumulate_blocks(
            q, scales, acc2d, out_dtype=jnp.float32, interpret=interpret)
    else:
        x2d = ref.dequantize_accumulate_blocks(q, scales, acc2d,
                                               out_dtype=jnp.float32)
    flat = x2d.reshape(-1)[: meta.n]
    return flat.reshape(meta.shape).astype(meta.dtype)


def quantization_rmse(x: jax.Array, *, block: int = quant8.DEFAULT_BLOCK,
                      backend: str = "jnp") -> jax.Array:
    """Convenience: RMS error of a quantize/dequantize round trip."""
    q, s, meta = quantize(x, block=block, backend=backend)
    xr = dequantize(q, s, meta, backend=backend)
    return jnp.sqrt(jnp.mean((x.astype(jnp.float32) - xr.astype(jnp.float32)) ** 2))
