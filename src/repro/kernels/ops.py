"""Public, shape-polymorphic wrappers over the quantization kernels.

`quantize`/`quantize_ef`/`dequantize` accept arbitrary-shaped tensors: they
flatten, pad to the kernel's (TILE_ROWS x block) tiling, and restore shape on
the way back. The wire cast is folded into the kernels (both backends cast
on the tile/oracle side), so bf16 wire buffers are consumed directly without
a materialized f32 copy.

Backend policy (`wire_backend`, the single policy every comm call site
resolves through -- repro.core.collectives/hier take a ``backend`` argument
and the CommEngine records the resolved choice in its EnginePlan):

  * "pallas"  -- pl.pallas_call (compiled on TPU; interpret=True elsewhere,
                 which validates the kernels but is far slower than XLA).
  * "jnp"     -- the pure-jnp oracle (identical math; used inside GSPMD-
                 partitioned regions and as the CPU default).
  * "auto"    -- pallas on TPU; elsewhere the REPRO_QUANT_BACKEND env var
                 ("pallas" runs the interpret-validated kernels) or jnp.
"""

from __future__ import annotations

import dataclasses
import functools
import os
from typing import Any

import jax
import jax.numpy as jnp

from repro.kernels import quant8, ref


@dataclasses.dataclass(frozen=True)
class QuantMeta:
    """Static metadata needed to invert `quantize`."""

    shape: tuple
    dtype: Any
    n: int                # true element count before padding
    block: int


@dataclasses.dataclass(frozen=True)
class PadInfo:
    """Padding a flat buffer pays to reach the (TILE_ROWS x block) tiling.

    `waste_frac` is large only for tiny buckets (n < TILE_ROWS * block): the
    engine records it per bucket (EnginePlan.quant_pad) so undersized int8
    buckets are visible in the plan instead of silently shipping padding."""

    n: int                # true element count
    padded: int           # elements after padding
    waste_elems: int

    @property
    def waste_frac(self) -> float:
        return self.waste_elems / max(self.padded, 1)


def pad_info(n: int, block: int = quant8.DEFAULT_BLOCK) -> PadInfo:
    row_quantum = block * quant8.TILE_ROWS
    padded = ((n + row_quantum - 1) // row_quantum) * row_quantum
    return PadInfo(n=n, padded=padded, waste_elems=padded - n)


def wire_backend(requested: str = "auto") -> str:
    """Resolve a requested backend against the single dispatch policy:
    pallas on TPU, interpret-validated pallas (REPRO_QUANT_BACKEND=pallas)
    or the jnp oracle elsewhere. Explicit requests pass through."""
    if requested != "auto":
        if requested not in ("pallas", "jnp"):
            raise ValueError(
                f"unknown quantization backend {requested!r}; expected "
                f"'auto', 'pallas' or 'jnp'")
        return requested
    if jax.default_backend() == "tpu":
        return "pallas"
    env = os.environ.get("REPRO_QUANT_BACKEND", "jnp")
    return env if env in ("pallas", "jnp") else "jnp"


_backend = wire_backend      # internal alias (pre-policy spelling)


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _to_blocks(x: jax.Array, block: int, *, pad_to: int | None = None):
    """Flatten + zero-pad to (n_blocks, block) with n_blocks % TILE_ROWS == 0.

    Keeps the input dtype (the kernels cast in-tile; see quantize_cast_blocks)
    so a bf16 wire buffer never materializes an f32 copy here. `pad_to`
    overrides the padded length when the buffer must match a mate that was
    padded to a larger collective quantum. Pad waste is reported via
    `pad_info` (the returned count is the true element count `n`)."""
    flat = x.reshape(-1)
    n = flat.shape[0]
    padded = pad_to if pad_to is not None else pad_info(n, block).padded
    flat = jnp.pad(flat, (0, padded - n))
    return flat.reshape(-1, block), n


def quantize(x: jax.Array, *, block: int = quant8.DEFAULT_BLOCK,
             backend: str = "auto"):
    """x (any shape, any float dtype) -> (q int8 (n_blocks, block), scales
    f32, QuantMeta). The cast to f32 happens inside the kernel/oracle."""
    be = wire_backend(backend)
    x2d, n = _to_blocks(x, block)
    if be == "pallas":
        q, s = quant8.quantize_cast_blocks(x2d, interpret=_interpret())
    else:
        q, s = ref.quantize_blocks(x2d)
    meta = QuantMeta(shape=tuple(x.shape), dtype=x.dtype, n=n, block=block)
    return q, s, meta


def quantize_ef(x: jax.Array, residual: jax.Array, *,
                block: int = quant8.DEFAULT_BLOCK, backend: str = "auto"):
    """Fused error-feedback quantize: one pass computing

        y = f32(x) + residual;  (q, s) = quantize(y);  new_res = y - q * s

    `residual` must have x's element count (any shape; flattened alongside).
    Returns (q, scales, QuantMeta, new_residual) with new_residual in
    residual's shape. Both backends run the identical expression graph, so
    jnp and (interpret-mode) pallas stay aligned and the jnp path is bitwise
    equal to composing quantize + dequantize_accumulate by hand.
    """
    be = wire_backend(backend)
    x2d, n = _to_blocks(x, block)
    r2d, rn = _to_blocks(residual.astype(jnp.float32), block,
                         pad_to=x2d.size)
    if rn != n:
        raise ValueError(
            f"residual has {rn} elements but the input has {n}")
    if be == "pallas":
        q, s, nr = quant8.quantize_ef_blocks(x2d, r2d,
                                             interpret=_interpret())
    else:
        q, s, nr = ref.quantize_ef_blocks(x2d, r2d)
    meta = QuantMeta(shape=tuple(x.shape), dtype=x.dtype, n=n, block=block)
    new_residual = nr.reshape(-1)[:n].reshape(residual.shape)
    return q, s, meta, new_residual


def dequantize(q: jax.Array, scales: jax.Array, meta: QuantMeta, *,
               backend: str = "auto") -> jax.Array:
    be = wire_backend(backend)
    if be == "pallas":
        x2d = quant8.dequantize_blocks(q, scales, out_dtype=jnp.float32,
                                       interpret=_interpret())
    else:
        x2d = ref.dequantize_blocks(q, scales, out_dtype=jnp.float32)
    flat = x2d.reshape(-1)[: meta.n]
    return flat.reshape(meta.shape).astype(meta.dtype)


def dequantize_accumulate(q: jax.Array, scales: jax.Array, acc: jax.Array,
                          meta: QuantMeta, *,
                          backend: str = "auto") -> jax.Array:
    """acc (same logical element count as the original tensor) + dequant(q),
    one fused pass on the gather side. `acc` is padded to q's (possibly
    collective-quantum) blocked size, so callers may hand in the unpadded
    accumulator. The result keeps ACC's dtype (accumulators stay f32 even
    when the quantized tensor was a bf16 wire buffer), reshaped to
    meta.shape."""
    be = wire_backend(backend)
    acc2d, _ = _to_blocks(acc, meta.block, pad_to=q.size)
    if be == "pallas":
        x2d = quant8.dequantize_accumulate_blocks(
            q, scales, acc2d, out_dtype=jnp.float32, interpret=_interpret())
    else:
        x2d = ref.dequantize_accumulate_blocks(q, scales, acc2d,
                                               out_dtype=jnp.float32)
    flat = x2d.reshape(-1)[: meta.n]
    return flat.reshape(meta.shape).astype(acc.dtype)


def quantization_rmse(x: jax.Array, *, block: int = quant8.DEFAULT_BLOCK,
                      backend: str = "jnp") -> jax.Array:
    """Convenience: RMS error of a quantize/dequantize round trip."""
    q, s, meta = quantize(x, block=block, backend=backend)
    xr = dequantize(q, s, meta, backend=backend)
    return jnp.sqrt(jnp.mean((x.astype(jnp.float32) - xr.astype(jnp.float32)) ** 2))
