"""Pallas TPU kernels for the performance-critical data paths:

  quant8     -- block int8 quantize / dequantize / dequant-accumulate
                (the low-precision communication wire format, paper C6)
  flashattn  -- online-softmax attention (VMEM-tiled forward kernel)
  ops        -- shape-polymorphic jit wrappers with backend selection
  ref        -- pure-jnp oracles (ground truth for tests; CPU fallback)
"""
