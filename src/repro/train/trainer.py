"""Training step factory: forward/backward + MLSL communication + optimizer.

Two first-class communication modes (DESIGN.md §4):

  * ``gspmd``  -- the baseline: pjit with partitioner-inserted gradient
    reductions; the priority scheduler contributes bucket ordering barriers
    between the gradients and the optimizer.

  * ``mlsl``   -- the paper's data path: the whole step runs inside a
    shard_map that is MANUAL over the batch ("pod"/"data") axes and AUTO over
    the model axis. Per-device gradients are fused into priority buckets and
    reduced explicitly through the CommEngine (repro.core.engine), which owns
    bucket planning, flat-vs-two-level routing, wire precision (fp32 / bf16 /
    int8 with optional error feedback) and the priority chain.

Gradient accumulation (``accum_steps > 1``) in mlsl mode reduces each
microbatch's buckets as they are produced (DDP-style) and accumulates the
*reduced* gradients; ``overlap=True`` software-pipelines that exchange so
microbatch k's buckets reduce interleaved with microbatch k+1's
forward/backward — the XLA-static analogue of MLSL's endpoint servers
progressing communication under compute. The two schedules compute
bit-identical fp32 values (same operations, different barrier structure).
With ``accum_steps == 1`` the step reduces once after the backward
(reduce-at-end), regardless of ``overlap``.

The returned step function is `jax.jit`-compatible with sharded TrainState /
Batch and is what launch/train.py, the dry-run, and the tests all use.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro import compat
from repro.core import scheduler
from repro.core.engine import CommConfig, CommEngine
from repro.core.planner import Planner
from repro.models.transformer import Batch, Model
from repro.optim import optimizers as opt_lib

__all__ = ["CommConfig", "TrainState", "make_train_state", "make_comm_engine",
           "make_train_step", "state_shardings"]


@dataclasses.dataclass
class TrainState:
    params: Any
    opt_state: Any
    step: jax.Array
    comm_residuals: Any = None       # error-feedback residuals per bucket


jax.tree_util.register_dataclass(
    TrainState, data_fields=["params", "opt_state", "step", "comm_residuals"],
    meta_fields=[])


def make_train_state(model: Model, optimizer: opt_lib.Optimizer,
                     key: jax.Array) -> TrainState:
    params = model.init(key)
    return TrainState(params=params, opt_state=optimizer.init(params),
                      step=jnp.zeros((), jnp.int32))


def _layer_index_fn():
    return scheduler.default_layer_index


def _batch_specs(planner: Planner, model: Model, batch_size: int) -> Batch:
    cfg = model.cfg
    tok = planner.tokens_spec(batch_size, extra_dims=1)
    three = planner.tokens_spec(batch_size, extra_dims=2)
    return Batch(
        tokens=tok, labels=tok, mask=None,
        img_embeds=three if cfg.vlm_img_tokens else None,
        frame_embeds=three if cfg.encoder is not None else None)


def state_shardings(planner: Planner, model: Model,
                    optimizer: opt_lib.Optimizer) -> TrainState:
    """PartitionSpec tree for TrainState (opt state mirrors params)."""
    defs = model.param_defs()
    pspecs = planner.tree_specs(defs, stacked_paths=Model.stacked_path)
    params_shape = jax.eval_shape(lambda: jax.tree_util.tree_map(
        lambda pd: jnp.zeros(pd.shape, pd.dtype), defs, is_leaf=_is_pd))
    opt_shape = jax.eval_shape(optimizer.init, params_shape)
    # all in-tree optimizers keep {name: params-shaped tree} states
    opt_specs = {k: pspecs for k in opt_shape}
    return TrainState(params=pspecs, opt_state=opt_specs,
                      step=P(), comm_residuals=None)


def _grad_struct(model: Model):
    """Abstract f32 gradient tree matching the parameter structure."""
    return jax.eval_shape(
        lambda: jax.tree_util.tree_map(
            lambda pd: jnp.zeros(pd.shape, jnp.float32),
            model.param_defs(), is_leaf=_is_pd))


def make_comm_engine(model: Model, mesh: Mesh, planner: Planner,
                     comm: CommConfig) -> CommEngine:
    """The model's CommEngine: bucket plan + routing from its parameter
    structure and sharding groups (the glue the Session facade and the
    benchmarks also use)."""
    grad_struct = _grad_struct(model)
    # fuse only within same-sharding groups: flattening a tensor that is
    # sharded over the (auto) model axis would reshard it
    pspecs = planner.tree_specs(model.param_defs(),
                                stacked_paths=Model.stacked_path)
    spec_by_path = {jax.tree_util.keystr(path): spec for path, spec in
                    jax.tree_util.tree_leaves_with_path(
                        pspecs, is_leaf=lambda x: isinstance(x, P))}

    def group_key(path):
        return str(spec_by_path.get(jax.tree_util.keystr(path), P()))

    def leaf_replicated(path):
        spec = spec_by_path.get(jax.tree_util.keystr(path), P())
        return all(a is None for a in spec)

    hybrid = planner.hybrid
    if hybrid is None:
        return CommEngine.create(grad_struct, comm, mesh, planner.batch_axes,
                                 layer_index=_layer_index_fn(),
                                 group_key=group_key,
                                 leaf_replicated=leaf_replicated)

    # Hybrid execution: the engine runs inside a manual region over
    # data_axes + tp_axis, so it plans on what each rank actually reduces —
    # model-sharded leaves shrink to their local 1/tp shard.
    def leaf_sharded(path):
        return not leaf_replicated(path)

    def shard_struct(path, leaf):
        spec = spec_by_path.get(jax.tree_util.keystr(path), P())
        shape = list(leaf.shape)
        for d, ax in enumerate(spec):
            if ax == hybrid.tp_axis:
                shape[d] //= hybrid.tp
        return jax.ShapeDtypeStruct(tuple(shape), leaf.dtype)

    local_struct = jax.tree_util.tree_map_with_path(shard_struct, grad_struct)
    return CommEngine.create(local_struct, comm, mesh, hybrid.data_axes,
                             layer_index=_layer_index_fn(),
                             group_key=group_key,
                             leaf_replicated=leaf_replicated,
                             tp_axis=hybrid.tp_axis,
                             leaf_sharded=leaf_sharded)


def make_train_step(model: Model, optimizer: opt_lib.Optimizer, mesh: Mesh,
                    planner: Planner, comm: CommConfig,
                    *, grad_clip: float = 1.0):
    """Returns train_step(state, batch) -> (state, metrics)."""
    cfg = model.cfg
    data_axes = planner.batch_axes
    fsdp_axes = planner.batch_axes if planner.fsdp else ()
    if comm.overlap and comm.mode != "mlsl":
        raise ValueError("CommConfig(overlap=True) needs the explicit mlsl "
                         "data path; gspmd reductions are partitioner-"
                         "inserted and cannot be pipelined from here")

    # Hybrid (data x model) execution: the step goes manual over the batch
    # axes AND the tp axis; parameters/optimizer state enter as local shards
    # per the planner's per-layer specs, model-sharded layers exchange
    # activations through the f/g collectives, and the engine splits the
    # gradient reduction (sharded leaves over data axes only).
    hybrid = planner.hybrid
    tp_axis = hybrid.tp_axis if hybrid is not None else None
    if hybrid is not None and comm.mode != "mlsl":
        raise ValueError("hybrid execution (planner.hybrid) needs comm mode "
                         "'mlsl': the activation f/g collectives and the "
                         "split gradient reduction run inside the explicit "
                         "manual data path")

    # mlsl mode runs the step in a shard_map manual over the batch axes (plus
    # the tp axis under hybrid); if any OTHER mesh axis is >1 the region is
    # PARTIAL-manual, which on JAX 0.4.x cannot contain scan loops
    # (compat.PARTIAL_MANUAL_SCAN_OK) -- unroll the block/accum scans there
    # (pattern_repeats is small for the smoke configs this CPU path runs;
    # mesh-scale dry-runs use gspmd).
    manual_axes = tuple(data_axes) + ((tp_axis,) if tp_axis else ())
    partial_manual = any(mesh.shape[a] > 1 for a in mesh.axis_names
                         if a not in manual_axes)
    unroll_scans = (comm.mode == "mlsl" and partial_manual
                    and not compat.PARTIAL_MANUAL_SCAN_OK)

    loss_kw = dict(moe_impl=comm.moe_impl, mesh=mesh,
                   batch_axes=data_axes, fsdp_axes=fsdp_axes,
                   wgather_wire=comm.wgather_wire) \
        if comm.moe_impl == "ep" else {}
    if comm.kv_chunk:
        loss_kw["kv_chunk"] = comm.kv_chunk
    if unroll_scans:
        loss_kw["unroll"] = True
    if tp_axis is not None:
        # blocks detect model-sharded weights by their shard shapes and
        # place the f/g activation collectives; DP-fallback layers see
        # full-size (replicated) weights and ignore the axis
        loss_kw["tp_axis"] = tp_axis

    def loss_fn(params, batch: Batch):
        return model.loss(params, batch, **loss_kw)

    def _split_micro(batch, acc):
        def split(x):
            assert x.shape[0] % acc == 0, (x.shape, acc)
            return x.reshape(acc, x.shape[0] // acc, *x.shape[1:])
        return jax.tree_util.tree_map(split, batch)

    def grads_fn(params, batch: Batch):
        """(loss, grads), microbatched over comm.accum_steps (C3: large
        global batches at bounded activation memory). Gradients here are
        UNREDUCED (local); used by gspmd (partitioner reduces) and by the
        mlsl accum_steps == 1 path (engine reduces at end)."""
        if comm.accum_steps <= 1:
            return jax.value_and_grad(loss_fn)(params, batch)
        acc = comm.accum_steps
        micro = _split_micro(batch, acc)
        gz = jax.tree_util.tree_map(
            lambda x: jnp.zeros(x.shape, jnp.float32), params)

        def body(carry, mb):
            gsum, lsum = carry
            loss, g = jax.value_and_grad(loss_fn)(params, mb)
            gsum = jax.tree_util.tree_map(
                lambda a, b: a + b.astype(jnp.float32), gsum, g)
            return (gsum, lsum + loss), None

        (gsum, lsum), _ = compat.maybe_scan(body, (gz, jnp.zeros(())), micro,
                                            unroll=unroll_scans)
        grads = jax.tree_util.tree_map(
            lambda g, pp: (g / acc).astype(pp.dtype), gsum, params)
        return lsum / acc, grads

    if comm.mode == "gspmd":
        def train_step(state: TrainState, batch: Batch):
            loss, grads = grads_fn(state.params, batch)
            grads, gnorm = opt_lib.clip_by_global_norm(grads, grad_clip)
            if comm.prioritize:
                # barrier-chain only (fuse=False): under GSPMD the reductions
                # are partitioner-inserted and fusing sharded leaves would
                # force all-gathers (§Perf iteration A0)
                plan = scheduler.plan_buckets(
                    grads, _layer_index_fn(), bucket_bytes=comm.bucket_bytes)
                grads = scheduler.reduce_with_priority(
                    grads, lambda flat, b: flat, plan, prioritize=True,
                    fuse=False)
            params, opt_state = optimizer.update(grads, state.opt_state,
                                                 state.params, state.step)
            new = TrainState(params=params, opt_state=opt_state,
                             step=state.step + 1,
                             comm_residuals=state.comm_residuals)
            return new, {"loss": loss, "grad_norm": gnorm}
        return train_step

    assert comm.mode == "mlsl", comm.mode
    assert not planner.fsdp, ("comm=mlsl manages gradient communication "
                              "explicitly and requires replicated (non-FSDP) "
                              "parameters over the batch axes; use gspmd for "
                              "ZeRO-sharded giants")

    # The engine owns the whole bucket-reduction data path: planning,
    # flat-vs-two-level routing, wire precision, error feedback, priority
    # chain.
    engine = make_comm_engine(model, mesh, planner, comm)

    if tp_axis is None:
        pspecs = None
        clip_grads = opt_lib.clip_by_global_norm
    else:
        pspecs = planner.tree_specs(model.param_defs(),
                                    stacked_paths=Model.stacked_path)
        sharded_flags = [any(ax == tp_axis for ax in s)
                         for s in jax.tree_util.tree_leaves(
                             pspecs, is_leaf=lambda x: isinstance(x, P))]

        def clip_grads(grads, max_norm):
            """opt_lib.clip_by_global_norm with the model-sharded leaves'
            sum-of-squares psum'd over the tp axis (each rank holds a
            distinct shard; replicated leaves are counted once). The norm
            comes out replicated everywhere, so replicated parameters keep
            taking identical updates across the tp group."""
            leaves = jax.tree_util.tree_leaves(grads)
            z = jnp.zeros((), jnp.float32)
            sq_sh = sum((jnp.sum(g.astype(jnp.float32) ** 2)
                         for g, sh in zip(leaves, sharded_flags) if sh), z)
            sq_rep = sum((jnp.sum(g.astype(jnp.float32) ** 2)
                          for g, sh in zip(leaves, sharded_flags) if not sh),
                         z)
            gn = jnp.sqrt(sq_rep + jax.lax.psum(sq_sh, tp_axis))
            scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
            return jax.tree_util.tree_map(
                lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                grads), gn

    def _to_f32(tree):
        return jax.tree_util.tree_map(
            lambda x: x.astype(jnp.float32), tree)

    def accum_reduce(params, batch: Batch, residuals):
        """Per-microbatch exchange over the accumulation scan.

        Each microbatch's gradients are reduced (mean over ranks) and the
        REDUCED gradients accumulated. overlap=False is the blocking
        baseline: the barrier token gates microbatch k+1's inputs on
        microbatch k's reduction chain retiring. overlap=True software-
        pipelines: microbatch k's reduction is issued with no data
        dependence on microbatch k+1's compute (only the collective chain
        itself is token-ordered), so the compiler may overlap the two —
        MLSL's EP servers, expressed statically. Both schedules perform the
        identical fp32 operation sequence, so they are bit-identical.

        The accumulator lives in the engine's BUCKET layout (one flat f32
        buffer per fused bucket, engine.init_accum) rather than as a
        gradient tree: the per-microbatch add then rides the gather-side
        dequantize_accumulate pass on the int8 wire (and stays one
        bucket-sized add on float wires) instead of a full extra
        read+write of the model per microbatch. The tree is restored once,
        after the last microbatch (engine.unfuse_accum).
        """
        acc = comm.accum_steps
        micro = _split_micro(batch, acc)
        token0 = jnp.zeros((), jnp.float32)

        # Microbatch 0 is peeled out of the scan in BOTH schedules so the
        # loss_fn call sites match exactly (prologue + scan-of-rest): XLA
        # fuses a top-level instance and an in-scan-body instance of the
        # same function differently, and matched call sites are what makes
        # the two schedules bit-identical, not just close.
        mb0 = jax.tree_util.tree_map(lambda x: x[0], micro)
        rest = jax.tree_util.tree_map(lambda x: x[1:], micro)
        # named scopes (profile attribution only) are applied SYMMETRICALLY
        # across the blocking and overlap schedules — matched call sites are
        # part of the bit-identity contract above
        with jax.named_scope("microbatch/fwd_bwd"):
            loss0, g0 = jax.value_and_grad(loss_fn)(params, mb0)

        if not comm.overlap:
            # blocking baseline: reduce each microbatch's buckets before the
            # next microbatch's compute. Without prioritization the engine
            # does not thread its own token, so the gate is derived from
            # every bucket's accumulator instead — blocking must not
            # silently weaken under prioritize=False.
            def exchange(g, bacc, res, token):
                with jax.named_scope("microbatch/exchange"):
                    bacc, res, token = engine.reduce_accum_chained(
                        _to_f32(g), bacc, res, token)
                if not comm.prioritize:
                    token = engine.gate_token_accum(bacc)
                return bacc, res, token

            bacc, residuals, token = exchange(g0, engine.init_accum(),
                                              residuals, token0)

            def body(carry, mb):
                bacc, lsum, res, token = carry
                mb, token = scheduler.chain_barrier(mb, token)
                with jax.named_scope("microbatch/fwd_bwd"):
                    loss, g = jax.value_and_grad(loss_fn)(params, mb)
                bacc, res, token = exchange(g, bacc, res, token)
                return (bacc, lsum + loss, res, token), None

            (bacc, lsum, residuals, _), _ = compat.maybe_scan(
                body, (bacc, loss0, residuals, token), rest,
                unroll=unroll_scans)
        else:
            # software pipeline: iteration k reduces microbatch k-1's
            # buckets beside microbatch k's compute (the reduction chain is
            # token-ordered but carries no dependence on the compute); the
            # epilogue drains the last microbatch
            def body(carry, mb):
                bacc, lsum, pending, res, token = carry
                with jax.named_scope("microbatch/fwd_bwd"):
                    loss, g = jax.value_and_grad(loss_fn)(params, mb)
                with jax.named_scope("microbatch/exchange"):
                    bacc, res, token = engine.reduce_accum_chained(
                        pending, bacc, res, token)
                return (bacc, lsum + loss, _to_f32(g), res, token), None

            (bacc, lsum, pending, residuals, token), _ = compat.maybe_scan(
                body, (engine.init_accum(), loss0, _to_f32(g0), residuals,
                       token0), rest, unroll=unroll_scans)
            with jax.named_scope("microbatch/exchange"):
                bacc, residuals, _ = engine.reduce_accum_chained(
                    pending, bacc, residuals, token)

        gsum = engine.unfuse_accum(bacc)
        grads = jax.tree_util.tree_map(
            lambda g, pp: (g / acc).astype(pp.dtype), gsum, params)
        return lsum / acc, grads, residuals

    # shard_map specs: manual over batch axes only; model axis stays auto.
    bspec = data_axes if len(data_axes) > 1 else data_axes[0]
    replicated = P()

    def inner(params, opt_state, step, residuals, batch: Batch):
        # per-device local loss; gradient = d(local mean)/d(params)
        if comm.accum_steps > 1:
            loss, grads, residuals = accum_reduce(params, batch, residuals)
        else:
            loss, grads = grads_fn(params, batch)
            grads, residuals = engine.reduce(grads, residuals)
        grads, gnorm = clip_grads(grads, grad_clip)
        loss = jax.lax.pmean(loss, data_axes)
        params, opt_state = optimizer.update(grads, opt_state, params, step)
        return params, opt_state, residuals, loss, gnorm

    grad_treedef = engine.plan.buckets.treedef
    if tp_axis is None:
        params_specs = jax.tree_util.tree_unflatten(
            grad_treedef, [replicated] * grad_treedef.num_leaves)
    else:
        # per-layer hybrid sharding: model-parallel layers' weights enter as
        # local shards over tp_axis, everything else replicated
        params_specs = pspecs
    batch_in_specs = Batch(tokens=P(bspec), labels=P(bspec), mask=None,
                           img_embeds=P(bspec) if cfg.vlm_img_tokens else None,
                           frame_embeds=P(bspec) if cfg.encoder is not None
                           else None)
    res_spec = engine.residual_specs(P(bspec))

    def train_step(state: TrainState, batch: Batch):
        if tp_axis is None:
            opt_specs = jax.tree_util.tree_map(lambda _: replicated,
                                               state.opt_state,
                                               is_leaf=lambda x: x is None)
        else:
            # all in-tree optimizers keep {name: params-shaped tree} states
            opt_specs = {k: params_specs for k in state.opt_state}
        residuals = state.comm_residuals
        if engine.plan.use_ef and residuals is None:
            residuals = engine.init_residuals()

        out = compat.shard_map(
            inner, mesh=mesh,
            in_specs=(params_specs, opt_specs, replicated, res_spec,
                      batch_in_specs),
            out_specs=(params_specs, opt_specs, res_spec, replicated,
                       replicated),
            axis_names=set(manual_axes), check_vma=False,
        )(state.params, state.opt_state, state.step, residuals, batch)
        params, opt_state, residuals, loss, gnorm = out
        new = TrainState(params=params, opt_state=opt_state,
                         step=state.step + 1, comm_residuals=residuals)
        return new, {"loss": loss, "grad_norm": gnorm}

    return train_step


def _is_pd(x):
    from repro.core.planner import ParamDef
    return isinstance(x, ParamDef)
