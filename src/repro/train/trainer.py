"""Training step factory: forward/backward + MLSL communication + optimizer.

Two first-class communication modes (DESIGN.md §4):

  * ``gspmd``  -- the baseline: pjit with partitioner-inserted gradient
    reductions; the priority scheduler contributes bucket ordering barriers
    between the gradients and the optimizer.

  * ``mlsl``   -- the paper's data path: the whole step runs inside a
    shard_map that is MANUAL over the batch ("pod"/"data") axes and AUTO over
    the model axis. Per-device gradients are fused into priority buckets and
    reduced explicitly through repro.core.collectives with a selectable wire
    precision (fp32 / bf16 / int8 with optional error feedback). First-layer
    buckets are chained ahead of bulk buckets, reproducing MLSL's message
    prioritization in the compiled HLO.

The returned step function is `jax.jit`-compatible with sharded TrainState /
Batch and is what launch/train.py, the dry-run, and the tests all use.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import compat
from repro.core import collectives, hw, scheduler
from repro.core import hier as hier_lib
from repro.core import planner as planner_lib
from repro.core.planner import Planner
from repro.models.transformer import Batch, Model
from repro.optim import optimizers as opt_lib


@dataclasses.dataclass(frozen=True)
class CommConfig:
    mode: str = "gspmd"              # gspmd | mlsl
    wire: str = collectives.WIRE_FP32
    prioritize: bool = True
    bucket_bytes: float = 25e6
    error_feedback: bool = False     # int8 wire only
    moe_impl: str = "gather"         # gather | ep  (expert-parallel a2a)
    accum_steps: int = 1             # microbatch gradient accumulation
    kv_chunk: int = 0                # >0: online-softmax attention chunking
    wgather_wire: str = "bf16"       # int8: quantized ZeRO weight gathers (ep)
    kv_dtype: str = "native"         # int8: quantized GQA KV cache (serving)
    # two-level collectives over a ("node", "local") factored data dimension
    # (repro.core.hier): `wire` selects the inter-node fabric leg and
    # `wire_intra` the intra-node legs (None: hier.default_wire_intra).
    # `topo` optionally names a machine hierarchy (repro.core.hw.TOPOLOGIES);
    # when set, each fused bucket is routed flat vs two-level by the
    # per-level cost model (scheduler.route_buckets) instead of always
    # taking the hierarchical path.
    hier: bool = False
    wire_intra: Optional[str] = None
    topo: Optional[str] = None


@dataclasses.dataclass
class TrainState:
    params: Any
    opt_state: Any
    step: jax.Array
    comm_residuals: Any = None       # error-feedback residuals per bucket


jax.tree_util.register_dataclass(
    TrainState, data_fields=["params", "opt_state", "step", "comm_residuals"],
    meta_fields=[])


def make_train_state(model: Model, optimizer: opt_lib.Optimizer,
                     key: jax.Array) -> TrainState:
    params = model.init(key)
    return TrainState(params=params, opt_state=optimizer.init(params),
                      step=jnp.zeros((), jnp.int32))


def _layer_index_fn():
    return scheduler.default_layer_index


def _batch_specs(planner: Planner, model: Model, batch_size: int) -> Batch:
    cfg = model.cfg
    tok = planner.tokens_spec(batch_size, extra_dims=1)
    three = planner.tokens_spec(batch_size, extra_dims=2)
    return Batch(
        tokens=tok, labels=tok, mask=None,
        img_embeds=three if cfg.vlm_img_tokens else None,
        frame_embeds=three if cfg.encoder is not None else None)


def state_shardings(planner: Planner, model: Model,
                    optimizer: opt_lib.Optimizer) -> TrainState:
    """PartitionSpec tree for TrainState (opt state mirrors params)."""
    defs = model.param_defs()
    pspecs = planner.tree_specs(defs, stacked_paths=Model.stacked_path)
    params_shape = jax.eval_shape(lambda: jax.tree_util.tree_map(
        lambda pd: jnp.zeros(pd.shape, pd.dtype), defs, is_leaf=_is_pd))
    opt_shape = jax.eval_shape(optimizer.init, params_shape)
    # all in-tree optimizers keep {name: params-shaped tree} states
    opt_specs = {k: pspecs for k in opt_shape}
    return TrainState(params=pspecs, opt_state=opt_specs,
                      step=P(), comm_residuals=None)


def make_train_step(model: Model, optimizer: opt_lib.Optimizer, mesh: Mesh,
                    planner: Planner, comm: CommConfig,
                    *, grad_clip: float = 1.0):
    """Returns (train_step(state, batch) -> (state, metrics), specs dict)."""
    cfg = model.cfg
    data_axes = planner.batch_axes
    fsdp_axes = planner.batch_axes if planner.fsdp else ()

    # mlsl mode runs the step in a shard_map manual over the batch axes; if
    # any OTHER mesh axis is >1 the region is PARTIAL-manual, which on JAX
    # 0.4.x cannot contain scan loops (compat.PARTIAL_MANUAL_SCAN_OK) --
    # unroll the block/accum scans there (pattern_repeats is small for the
    # smoke configs this CPU path runs; mesh-scale dry-runs use gspmd).
    partial_manual = any(mesh.shape[a] > 1 for a in mesh.axis_names
                         if a not in data_axes)
    unroll_scans = (comm.mode == "mlsl" and partial_manual
                    and not compat.PARTIAL_MANUAL_SCAN_OK)

    loss_kw = dict(moe_impl=comm.moe_impl, mesh=mesh,
                   batch_axes=data_axes, fsdp_axes=fsdp_axes,
                   wgather_wire=comm.wgather_wire) \
        if comm.moe_impl == "ep" else {}
    if comm.kv_chunk:
        loss_kw["kv_chunk"] = comm.kv_chunk
    if unroll_scans:
        loss_kw["unroll"] = True

    def loss_fn(params, batch: Batch):
        return model.loss(params, batch, **loss_kw)

    def grads_fn(params, batch: Batch):
        """(loss, grads), microbatched over comm.accum_steps (C3: large
        global batches at bounded activation memory)."""
        if comm.accum_steps <= 1:
            return jax.value_and_grad(loss_fn)(params, batch)
        acc = comm.accum_steps

        def split(x):
            assert x.shape[0] % acc == 0, (x.shape, acc)
            return x.reshape(acc, x.shape[0] // acc, *x.shape[1:])

        micro = jax.tree_util.tree_map(split, batch)
        gz = jax.tree_util.tree_map(
            lambda x: jnp.zeros(x.shape, jnp.float32), params)

        def body(carry, mb):
            gsum, lsum = carry
            loss, g = jax.value_and_grad(loss_fn)(params, mb)
            gsum = jax.tree_util.tree_map(
                lambda a, b: a + b.astype(jnp.float32), gsum, g)
            return (gsum, lsum + loss), None

        (gsum, lsum), _ = compat.maybe_scan(body, (gz, jnp.zeros(())), micro,
                                            unroll=unroll_scans)
        grads = jax.tree_util.tree_map(
            lambda g, pp: (g / acc).astype(pp.dtype), gsum, params)
        return lsum / acc, grads

    if comm.mode == "gspmd":
        def train_step(state: TrainState, batch: Batch):
            loss, grads = grads_fn(state.params, batch)
            grads, gnorm = opt_lib.clip_by_global_norm(grads, grad_clip)
            if comm.prioritize:
                # barrier-chain only (fuse=False): under GSPMD the reductions
                # are partitioner-inserted and fusing sharded leaves would
                # force all-gathers (§Perf iteration A0)
                plan = scheduler.plan_buckets(
                    grads, _layer_index_fn(), bucket_bytes=comm.bucket_bytes)
                grads = scheduler.reduce_with_priority(
                    grads, lambda flat, b: flat, plan, prioritize=True,
                    fuse=False)
            params, opt_state = optimizer.update(grads, state.opt_state,
                                                 state.params, state.step)
            new = TrainState(params=params, opt_state=opt_state,
                             step=state.step + 1,
                             comm_residuals=state.comm_residuals)
            return new, {"loss": loss, "grad_norm": gnorm}
        return train_step

    assert comm.mode == "mlsl", comm.mode
    assert not planner.fsdp, ("comm=mlsl manages gradient communication "
                              "explicitly and requires replicated (non-FSDP) "
                              "parameters over the batch axes; use gspmd for "
                              "ZeRO-sharded giants")

    # Bucket plan is built from the (static) parameter structure.
    grad_struct = jax.eval_shape(
        lambda: jax.tree_util.tree_map(lambda pd: jnp.zeros(pd.shape,
                                                            jnp.float32),
                                       model.param_defs(),
                                       is_leaf=_is_pd))
    # fuse only within same-sharding groups: flattening a tensor that is
    # sharded over the (auto) model axis would reshard it
    pspecs = planner.tree_specs(model.param_defs(),
                                stacked_paths=Model.stacked_path)
    spec_by_path = {jax.tree_util.keystr(path): spec for path, spec in
                    jax.tree_util.tree_leaves_with_path(
                        pspecs, is_leaf=lambda x: isinstance(x, P))}

    def group_key(path):
        return str(spec_by_path.get(jax.tree_util.keystr(path), P()))

    def leaf_replicated(path):
        spec = spec_by_path.get(jax.tree_util.keystr(path), P())
        return all(a is None for a in spec)

    plan = scheduler.plan_buckets(grad_struct, _layer_index_fn(),
                                  bucket_bytes=comm.bucket_bytes,
                                  group_key=group_key)
    # which buckets may be fused into a flat message: only fully-replicated
    # leaves -- flattening a model-sharded gradient under the auto axis
    # reshards it (all-gathers over the node group; §Perf iteration A0/C2)
    leaf_paths = [path for path, _ in
                  jax.tree_util.tree_leaves_with_path(grad_struct)]
    bucket_fusable = tuple(
        all(leaf_replicated(leaf_paths[i]) for i in b.leaf_ids)
        for b in plan.buckets)
    dp = 1
    for a in data_axes:
        dp *= mesh.shape[a]

    use_ef = comm.error_feedback and comm.wire == collectives.WIRE_INT8

    use_hier = comm.hier
    if use_hier:
        assert hier_lib.NODE_AXIS in data_axes and \
            hier_lib.LOCAL_AXIS in data_axes, (
                "comm.hier needs the data dimension factored over "
                f"({hier_lib.NODE_AXIS!r}, {hier_lib.LOCAL_AXIS!r}) mesh "
                f"axes (launch.mesh.make_hier_mesh); got {data_axes}")
        wire_intra = comm.wire_intra or hier_lib.default_wire_intra(comm.wire)
        hier_spec = hier_lib.HierSpec(
            wire_intra=wire_intra, wire_inter=comm.wire,
            error_feedback=use_ef)
        n_node = mesh.shape[hier_lib.NODE_AXIS]
        n_local = mesh.shape[hier_lib.LOCAL_AXIS]
        if comm.topo is not None:
            if comm.topo not in hw.TOPOLOGIES:
                raise ValueError(
                    f"unknown topology {comm.topo!r}; known: "
                    f"{sorted(hw.TOPOLOGIES)}")
            # per-bucket flat-vs-two-level routing from the per-level cost
            # model: small latency-bound buckets may stay flat while bulk
            # buckets take the hierarchy (MLSL per-message phase choice)
            bucket_algos = scheduler.route_buckets(
                plan, hw.TOPOLOGIES[comm.topo], nodes=n_node)
        else:
            bucket_algos = tuple(planner_lib.ALGO_HIER
                                 for _ in plan.buckets)
    else:
        bucket_algos = tuple(planner_lib.ALGO_FLAT for _ in plan.buckets)

    def _bucket_hier(bi: int) -> bool:
        return bucket_algos[bi] == planner_lib.ALGO_HIER

    def init_residuals():
        """Global-view zero residuals: per-rank shard shape x dp ranks (the
        shard_map in_spec splits them back to one fabric shard per rank)."""
        if not use_ef:
            return None

        def shard(bi, b):
            if _bucket_hier(bi):
                return hier_lib.ef_residual_shape(b.n_elems, n_local,
                                                  n_node)[0]
            return collectives.ef_residual_shape(b.n_elems, dp)[0]

        return tuple(jnp.zeros((shard(bi, b) * dp,), jnp.float32)
                     for bi, b in enumerate(plan.buckets))

    def _reduce_flat(flat, residual, bi):
        """One fused message over the data axes: flat or two-level path per
        the bucket routing. Returns (reduced, new_residual_or_None)."""
        if _bucket_hier(bi):
            if use_ef:
                return hier_lib.hier_allreduce_ef(flat, residual, hier_spec,
                                                  mean=True)
            return hier_lib.hier_allreduce(flat, hier_spec, mean=True), None
        if use_ef:
            return collectives.allreduce_ef(flat, residual, data_axes,
                                            mean=True)
        return collectives.allreduce(flat, data_axes, wire=comm.wire,
                                     mean=True), None

    def _reduce_buckets(grads, residuals):
        """Fused, prioritized, wire-precision gradient exchange.

        Replicated buckets travel as one fused flat message (MLSL message
        fusion + optional int8 block quantization and error feedback).
        Model-sharded buckets are reduced per-leaf, shape-preserving (no
        resharding); the int8 wire's flatten/scatter composition would
        reshard them, so those leaves use the bf16 wire instead."""
        leaves = jax.tree_util.tree_leaves(grads)
        new_leaves = list(leaves)
        new_residuals = []
        token = None
        for bi, bucket in enumerate(plan.buckets):
            if bucket_fusable[bi]:
                flat = scheduler.fuse_bucket(leaves, bucket)
                if comm.prioritize:
                    flat, token = scheduler.chain_barrier(flat, token)
                red, res = _reduce_flat(flat,
                                        residuals[bi] if use_ef else None,
                                        bi)
                if use_ef:
                    new_residuals.append(res)
                if comm.prioritize:
                    token = scheduler._token_of(red)
                for lid, leaf in scheduler.unfuse_bucket(red, bucket).items():
                    new_leaves[lid] = leaf
            else:
                vals = [leaves[i] for i in bucket.leaf_ids]
                if comm.prioritize:
                    vals, token = scheduler.chain_barrier(vals, token)
                wire = comm.wire if comm.wire != collectives.WIRE_INT8                     else collectives.WIRE_BF16
                vals = [collectives.allreduce(v, data_axes, wire=wire,
                                              mean=True) for v in vals]
                if use_ef:
                    new_residuals.append(residuals[bi])
                if comm.prioritize:
                    token = scheduler._token_of(vals[0])
                for lid, leaf in zip(bucket.leaf_ids, vals):
                    new_leaves[lid] = leaf
        out = jax.tree_util.tree_unflatten(plan.treedef, new_leaves)
        return out, (tuple(new_residuals) if use_ef else None)

    # shard_map specs: manual over batch axes only; model axis stays auto.
    bspec = data_axes if len(data_axes) > 1 else data_axes[0]
    replicated = P()

    def inner(params, opt_state, step, residuals, batch: Batch):
        # per-device local loss; gradient = d(local mean)/d(params)
        loss, grads = grads_fn(params, batch)
        grads, residuals = _reduce_buckets(grads, residuals)
        grads, gnorm = opt_lib.clip_by_global_norm(grads, grad_clip)
        loss = jax.lax.pmean(loss, data_axes)
        params, opt_state = optimizer.update(grads, opt_state, params, step)
        return params, opt_state, residuals, loss, gnorm

    params_specs = jax.tree_util.tree_map(lambda _: replicated,
                                          grad_struct)
    batch_in_specs = Batch(tokens=P(bspec), labels=P(bspec), mask=None,
                           img_embeds=P(bspec) if cfg.vlm_img_tokens else None,
                           frame_embeds=P(bspec) if cfg.encoder is not None
                           else None)
    res_spec = (tuple(P(bspec) for _ in plan.buckets) if use_ef else None)

    def train_step(state: TrainState, batch: Batch):
        opt_specs = jax.tree_util.tree_map(lambda _: replicated,
                                           state.opt_state,
                                           is_leaf=lambda x: x is None)
        residuals = state.comm_residuals
        if use_ef and residuals is None:
            residuals = init_residuals()

        out = compat.shard_map(
            inner, mesh=mesh,
            in_specs=(params_specs, opt_specs, replicated, res_spec,
                      batch_in_specs),
            out_specs=(params_specs, opt_specs, res_spec, replicated,
                       replicated),
            axis_names=set(data_axes), check_vma=False,
        )(state.params, state.opt_state, state.step, residuals, batch)
        params, opt_state, residuals, loss, gnorm = out
        new = TrainState(params=params, opt_state=opt_state,
                         step=state.step + 1, comm_residuals=residuals)
        return new, {"loss": loss, "grad_norm": gnorm}

    return train_step


def _is_pd(x):
    from repro.core.planner import ParamDef
    return isinstance(x, ParamDef)
