"""Compute-to-communication (C2C) ratio analysis.

This is the paper's analytical foundation (Section "Design choices and
insights", following Das et al. 2016, arXiv:1602.06709): for every layer,
compute the number of compute operations per communicated byte under each
parallelization strategy, and pick the strategy that maximizes the ratio.

Key paper insight reproduced here (and property-tested in
tests/test_properties.py):

  * Under *data parallelism* the C2C ratio of a conv layer is a function of
    the output-featuremap size and the mini-batch (and overlap), and is
    INDEPENDENT of kernel size, #input/#output feature maps, and stride.
  * The ratio is proportional to the mini-batch -> strong-scaling shrinks the
    per-node batch and communication starts to dominate (motivates
    large-batch training, C3).
  * Under *model parallelism* activations are exchanged instead of weight
    gradients, flipping which layers are cheap to distribute.
  * *Hybrid parallelism* interpolates with a node-group size g: model
    parallelism inside a group of g nodes, data parallelism across p/g
    groups.
"""

from __future__ import annotations

import dataclasses
import enum
import math
from typing import Sequence

from repro.core import hw


class LayerKind(str, enum.Enum):
    CONV = "conv"
    FC = "fc"                  # fully-connected / generic matmul projection
    ATTENTION = "attention"    # self-attention block (proj + score/context)
    MOE = "moe"                # expert-parallel MLP
    SSM = "ssm"                # state-space (SSD) mixer
    EMBED = "embed"
    NORM = "norm"


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    """Shape summary of one layer, enough for the C2C analysis.

    For convs: weight_elems = K*K*Cin*Cout, out_elems_per_sample = Ho*Wo*Cout.
    For matmuls: weight_elems = Din*Dout, out_elems_per_sample = S*Dout.
    flops_fwd_per_sample counts one forward pass of ONE sample.
    """

    name: str
    kind: LayerKind
    weight_elems: float
    out_elems_per_sample: float
    flops_fwd_per_sample: float
    # multiplier for backward work relative to forward (dgrad + wgrad).
    bwd_flops_factor: float = 2.0


class Strategy(str, enum.Enum):
    DATA = "data"
    MODEL = "model"
    HYBRID = "hybrid"


@dataclasses.dataclass(frozen=True)
class StrategyChoice:
    strategy: Strategy
    group_size: int            # model-parallel node-group size g (1 == data)
    ratio: float               # achieved C2C ratio (flops per byte)
    comm_bytes: float          # bytes communicated per iteration per node


def _iter_flops(layer: LayerSpec, batch: int) -> float:
    return layer.flops_fwd_per_sample * batch * (1.0 + layer.bwd_flops_factor)


def data_parallel_ratio(layer: LayerSpec, batch: int, p: int,
                        bytes_per_elem: float = 4.0) -> float:
    """FLOPs per communicated byte with pure data parallelism.

    Communication = ring allreduce of the weight gradient: each node moves
    ~2 * W * (p-1)/p bytes per iteration regardless of batch, so the ratio
    grows linearly with the batch -- the paper's large-batch argument.
    """
    if layer.weight_elems == 0:
        return math.inf
    comm = 2.0 * layer.weight_elems * bytes_per_elem * (p - 1) / max(p, 1)
    if comm == 0:
        return math.inf
    return _iter_flops(layer, batch) / comm


def model_parallel_ratio(layer: LayerSpec, batch: int, g: int,
                         bytes_per_elem: float = 4.0) -> float:
    """FLOPs per byte with the layer model-partitioned across g nodes.

    Communication = activations + activation gradients crossing the partition
    (allgather of the layer output and the reverse in backprop), which scales
    with batch * output size; weights never move.
    """
    if g <= 1:
        return math.inf
    comm = 2.0 * layer.out_elems_per_sample * batch * bytes_per_elem \
        * (g - 1) / g
    if comm == 0:
        return math.inf
    return _iter_flops(layer, batch) / comm


def hybrid_ratio(layer: LayerSpec, batch: int, p: int, g: int,
                 bytes_per_elem: float = 4.0) -> float:
    """Node groups of size g: model parallel inside, data parallel across.

    Per-node communication is the sum of (a) activation exchange inside the
    group (batch is divided across the p/g groups -> local batch b*g/p...
    actually each group processes batch/(p/g) samples) and (b) the weight-
    gradient allreduce across groups of the 1/g weight shard.
    g == 1 degenerates to pure data parallelism, g == p to pure model
    parallelism -- the paper's 'two extreme design points'.
    """
    if p % g != 0:
        return 0.0
    groups = p // g
    local_batch = batch / groups
    comm = 0.0
    if g > 1:
        comm += 2.0 * layer.out_elems_per_sample * local_batch \
            * bytes_per_elem * (g - 1) / g
    if groups > 1:
        comm += 2.0 * (layer.weight_elems / g) * bytes_per_elem \
            * (groups - 1) / groups
    if comm == 0:
        return math.inf
    return _iter_flops(layer, batch) / comm


def choose_strategy(layer: LayerSpec, batch: int, p: int,
                    group_sizes: Sequence[int] | None = None,
                    bytes_per_elem: float = 4.0) -> StrategyChoice:
    """Pick the node-group size maximizing the C2C ratio for this layer.

    This is the paper's 'choosing the right work partitioning strategy':
    evaluated per layer, because conv-like layers (small weights, large
    activations) prefer data parallelism while FC-like layers (large weights,
    small activations) prefer model/hybrid parallelism.
    """
    if group_sizes is None:
        group_sizes = [g for g in (1, 2, 4, 8, 16, 32) if g <= p and p % g == 0]
    best_g, best_r = 1, -1.0
    for g in group_sizes:
        r = hybrid_ratio(layer, batch, p, g, bytes_per_elem)
        if r > best_r:
            best_g, best_r = g, r
    if best_g == 1:
        strat = Strategy.DATA
    elif best_g == p:
        strat = Strategy.MODEL
    else:
        strat = Strategy.HYBRID
    flops = _iter_flops(layer, batch)
    comm = flops / best_r if best_r not in (0.0, math.inf) else 0.0
    return StrategyChoice(strategy=strat, group_size=best_g, ratio=best_r,
                          comm_bytes=comm)


# --- convenience constructors ------------------------------------------------

def conv_layer(name: str, cin: int, cout: int, k: int, h_out: int, w_out: int,
               stride: int = 1) -> LayerSpec:
    del stride  # the ratio does not depend on it -- kept to document the claim
    flops = 2.0 * cin * cout * k * k * h_out * w_out
    return LayerSpec(name=name, kind=LayerKind.CONV,
                     weight_elems=float(cin * cout * k * k),
                     out_elems_per_sample=float(h_out * w_out * cout),
                     flops_fwd_per_sample=flops)


def fc_layer(name: str, din: int, dout: int, seq: int = 1) -> LayerSpec:
    flops = 2.0 * din * dout * seq
    return LayerSpec(name=name, kind=LayerKind.FC,
                     weight_elems=float(din * dout),
                     out_elems_per_sample=float(dout * seq),
                     flops_fwd_per_sample=flops)


def attention_layer(name: str, d_model: int, n_heads: int, head_dim: int,
                    n_kv: int, seq: int) -> LayerSpec:
    proj_w = d_model * (n_heads * head_dim + 2 * n_kv * head_dim
                        + n_heads * head_dim)
    proj_flops = 2.0 * seq * proj_w
    score_flops = 2.0 * 2.0 * seq * seq * n_heads * head_dim * 0.5  # causal
    return LayerSpec(name=name, kind=LayerKind.ATTENTION,
                     weight_elems=float(proj_w),
                     out_elems_per_sample=float(seq * d_model),
                     flops_fwd_per_sample=proj_flops + score_flops)


def mlp_layer(name: str, d_model: int, d_ff: int, seq: int,
              gated: bool = True) -> LayerSpec:
    n_mats = 3 if gated else 2
    w = n_mats * d_model * d_ff
    return LayerSpec(name=name, kind=LayerKind.FC,
                     weight_elems=float(w),
                     out_elems_per_sample=float(seq * d_model),
                     flops_fwd_per_sample=2.0 * seq * w)


def moe_layer(name: str, d_model: int, d_ff: int, n_experts: int, top_k: int,
              seq: int, gated: bool = True) -> LayerSpec:
    n_mats = 3 if gated else 2
    w = n_experts * n_mats * d_model * d_ff
    active = top_k * n_mats * d_model * d_ff
    return LayerSpec(name=name, kind=LayerKind.MOE,
                     weight_elems=float(w),
                     out_elems_per_sample=float(seq * d_model),
                     flops_fwd_per_sample=2.0 * seq * active)


def ssm_layer(name: str, d_model: int, d_inner: int, d_state: int,
              seq: int) -> LayerSpec:
    w = d_model * 2 * d_inner + d_inner * d_model
    flops = 2.0 * seq * w + 2.0 * seq * d_inner * d_state * 2
    return LayerSpec(name=name, kind=LayerKind.SSM,
                     weight_elems=float(w),
                     out_elems_per_sample=float(seq * d_model),
                     flops_fwd_per_sample=flops)


def embed_layer(name: str, vocab: int, d_model: int, seq: int) -> LayerSpec:
    return LayerSpec(name=name, kind=LayerKind.EMBED,
                     weight_elems=float(vocab * d_model),
                     out_elems_per_sample=float(seq * d_model),
                     flops_fwd_per_sample=0.0)


# --- whole-model layer lists (the analysis→execution bridge) -----------------

def block_layer(name: str, kind: str, cfg, seq: int,
                repeats: int = 1) -> LayerSpec:
    """One LayerSpec for a whole transformer block (mixer + MLP).

    `cfg` is a repro.configs.base.ModelConfig; `kind` one of its block
    kinds. out_elems_per_sample counts BOTH residual-stream outputs (the
    mixer's and the MLP's) — i.e. the two activation psums an executed
    head/feature-sharded block exchanges per forward pass. `repeats` scales
    weights/activations/flops for stacked (scanned) pattern positions; the
    C2C ratios are invariant to it (every term scales by the same factor)
    but per-iteration comm totals need it.
    """
    d = cfg.d_model
    mlp_part = None
    if kind != "ssm" and kind != "moe":
        mlp_part = mlp_layer(name, d, cfg.d_ff, seq, gated=cfg.mlp_gated)
    if kind in ("attn", "local", "enc"):
        a = cfg.attn
        mix = attention_layer(name, d, a.n_heads, a.head_dim, a.n_kv, seq)
        kindk = LayerKind.ATTENTION
    elif kind == "cross":
        # self-attention + cross-attention: two attention stacks' weights
        a = cfg.attn
        one = attention_layer(name, d, a.n_heads, a.head_dim, a.n_kv, seq)
        mix = dataclasses.replace(
            one, weight_elems=2.0 * one.weight_elems,
            out_elems_per_sample=2.0 * one.out_elems_per_sample,
            flops_fwd_per_sample=2.0 * one.flops_fwd_per_sample)
        kindk = LayerKind.ATTENTION
    elif kind == "mla":
        m = cfg.mla
        w = (d * m.q_lora_rank
             + m.q_lora_rank * m.n_heads * (m.qk_nope_dim + m.qk_rope_dim)
             + d * (m.kv_lora_rank + m.qk_rope_dim)
             + m.kv_lora_rank * m.n_heads * (m.qk_nope_dim + m.v_head_dim)
             + m.n_heads * m.v_head_dim * d)
        score = 2.0 * 2.0 * seq * seq * m.n_heads \
            * (m.qk_nope_dim + m.qk_rope_dim) * 0.5
        mix = LayerSpec(name=name, kind=LayerKind.ATTENTION,
                        weight_elems=float(w),
                        out_elems_per_sample=float(seq * d),
                        flops_fwd_per_sample=2.0 * seq * w + score)
        kindk = LayerKind.ATTENTION
    elif kind == "moe":
        a = cfg.attn
        attn = attention_layer(name, d, a.n_heads, a.head_dim, a.n_kv, seq)
        m = cfg.moe
        moe = moe_layer(name, d, m.d_ff, m.n_experts, m.top_k, seq,
                        gated=cfg.mlp_gated)
        mix = LayerSpec(
            name=name, kind=LayerKind.MOE,
            weight_elems=attn.weight_elems + moe.weight_elems,
            out_elems_per_sample=attn.out_elems_per_sample
            + moe.out_elems_per_sample,
            flops_fwd_per_sample=attn.flops_fwd_per_sample
            + moe.flops_fwd_per_sample)
        kindk = LayerKind.MOE
    elif kind == "ssm":
        s = cfg.ssm
        mix = ssm_layer(name, d, s.expand * d, s.d_state, seq)
        kindk = LayerKind.SSM
    elif kind == "rglru":
        r = cfg.rglru
        w = 2.0 * d * r.lru_width + r.lru_width * d + 3.0 * r.lru_width
        mix = LayerSpec(name=name, kind=LayerKind.SSM,
                        weight_elems=float(w),
                        out_elems_per_sample=float(seq * d),
                        flops_fwd_per_sample=2.0 * seq * w)
        kindk = LayerKind.SSM
    else:
        raise ValueError(f"unknown block kind {kind!r}")
    w = mix.weight_elems + (mlp_part.weight_elems if mlp_part else 0.0)
    o = mix.out_elems_per_sample \
        + (mlp_part.out_elems_per_sample if mlp_part else 0.0)
    f = mix.flops_fwd_per_sample \
        + (mlp_part.flops_fwd_per_sample if mlp_part else 0.0)
    return LayerSpec(name=name, kind=kindk, weight_elems=w * repeats,
                     out_elems_per_sample=o * repeats,
                     flops_fwd_per_sample=f * repeats)


def layers_from_model_config(cfg, seq: int) -> list[LayerSpec]:
    """Per-layer LayerSpecs for a transformer ModelConfig, named after the
    parameter-tree keys (`embed`, `p{i}_{kind}` stacked pattern positions,
    `t{i}_{kind}` tail blocks, `head`) so per-layer strategy verdicts map
    1:1 onto parameter subtrees — planner.plan_hybrid consumes this to turn
    the chooser's table into an executed sharding."""
    out = [embed_layer("embed", cfg.vocab, cfg.d_model, seq)]
    reps = cfg.pattern_repeats
    if reps > 0:
        for i, kind in enumerate(cfg.block_pattern):
            out.append(block_layer(f"p{i}_{kind}", kind, cfg, seq,
                                   repeats=reps))
    for i, kind in enumerate(cfg.tail_layers):
        out.append(block_layer(f"t{i}_{kind}", kind, cfg, seq))
    if not cfg.tie_embeddings:
        out.append(fc_layer("head", cfg.d_model, cfg.vocab, seq))
    return out


# --- iteration-level summaries (used by simulator calibration) ---------------

def exposed_comm_upper_bound(layers: Sequence[LayerSpec], batch: int, p: int,
                             link: hw.Link,
                             bytes_per_elem: float = 4.0) -> float:
    """Sum of allreduce times with zero overlap (the BLOCKING policy bound)."""
    total = 0.0
    for l in layers:
        nbytes = l.weight_elems * bytes_per_elem
        total += hw.ring_allreduce_time(nbytes, p, link)
    return total
