"""MLSL-style Session facade (the paper's two framework interfaces, C7).

One object ties the library together the way MLSL's `Session`/`Distribution`
did for Caffe/TF/nGraph:

  * the *collectives* interface  -> `session.comm` (repro.core.collectives)
  * the *engine* interface       -> `session.comm_engine(model)` builds the
    CommEngine (repro.core.engine) that owns the model's whole bucket-
    reduction data path: bucket plan, flat-vs-hier routing, wire precision,
    error feedback, priority chain, overlap.
  * the *DL Layer* interface     -> `session.planner` picks per-layer
    partitioning from the C2C analysis and emits parameter/activation
    shardings; `session.make_train_step()` wires the engine into the
    training step.

This is also the integration surface a framework would adopt (the paper
integrates MLSL into Caffe/TensorFlow-Horovod/nGraph with exactly this kind
of thin adapter).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax

from repro.core import c2c, collectives, hier
from repro.core.engine import CommEngine
from repro.core.planner import Planner, make_planner, plan_report
from repro.models.transformer import Model
from repro.optim import optimizers as opt_lib
from repro.train import trainer as tr


@dataclasses.dataclass
class Session:
    mesh: jax.sharding.Mesh
    planner: Planner
    comm_cfg: tr.CommConfig

    @classmethod
    def create(cls, mesh: jax.sharding.Mesh, *, n_params: float = 0.0,
               train: bool = True, comm: Optional[tr.CommConfig] = None,
               hbm_budget: float = 16e9) -> "Session":
        planner = make_planner(mesh, n_params, train=train,
                               hbm_budget=hbm_budget)
        return cls(mesh=mesh, planner=planner,
                   comm_cfg=comm or tr.CommConfig())

    # --- collectives interface ------------------------------------------------

    @property
    def comm(self) -> collectives.Comm:
        # a ("node", "local")-factored data dimension makes the communicator
        # hierarchy-aware: Comm.allreduce routes through repro.core.hier
        batch = self.planner.batch_axes
        node = hier.NODE_AXIS if hier.NODE_AXIS in batch else None
        local = hier.LOCAL_AXIS if hier.LOCAL_AXIS in batch else None
        return collectives.Comm(mesh=self.mesh, data_axes=batch,
                                model_axis=self.planner.model_axis,
                                node_axis=node, local_axis=local)

    # --- engine interface -----------------------------------------------------

    def comm_engine(self, model: Model) -> CommEngine:
        """The CommEngine the train step will run: the model's bucket plan,
        per-bucket flat-vs-hier routes, and wire/EF/overlap configuration —
        inspectable ahead of compilation (benchmarks, schedule estimates)."""
        return tr.make_comm_engine(model, self.mesh, self.planner,
                                   self.comm_cfg)

    # --- DL layer interface ---------------------------------------------------

    def param_shardings(self, model: Model):
        return self.planner.tree_shardings(model.param_defs(),
                                           stacked_paths=Model.stacked_path)

    def layer_strategies(self, layers, batch: int):
        """The per-layer data/model/hybrid decision table (paper C1/C2)."""
        p = self.planner.batch_size_total * self.planner.model_size
        return plan_report(layers, batch, p)

    def make_train_step(self, model: Model, optimizer: opt_lib.Optimizer,
                        **kw):
        return tr.make_train_step(model, optimizer, self.mesh, self.planner,
                                  self.comm_cfg, **kw)

    def wire_savings(self) -> float:
        """Wire-bytes multiplier of the configured precision vs fp32 (C6)."""
        return (collectives.wire_bytes_per_elem(collectives.WIRE_FP32)
                / collectives.wire_bytes_per_elem(self.comm_cfg.wire))
