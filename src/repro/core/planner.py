"""The DL Layer API: per-layer/per-parameter work-partitioning (paper C2/C7).

The paper's higher-level interface lets a framework declare layers and have
the library pick the communication pattern implied by the parallelism chosen
for each layer (data / model / hybrid with node groups). Here the same role
is played by a planner that maps every parameter (and activation) to a
`PartitionSpec` over the production mesh:

  * the `model` mesh axis is the node group (model parallelism inside it);
  * the batch axes (`pod`, `data`) carry data parallelism across groups;
  * the C2C analysis (repro.core.c2c) picks data vs model vs hybrid per
    layer kind, and the planner additionally applies parameter/optimizer
    sharding over the batch axes (ZeRO/FSDP-style) when the replicated
    footprint would not fit the per-chip HBM budget.

Models declare parameters as `ParamDef`s with a *kind*; the planner owns the
kind -> sharding rules, so models stay distribution-agnostic (the paper's
argument for putting this logic in the library, not the framework).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import c2c, hw

# Parameter kinds understood by the planner.
K_EMBED = "embed"            # (vocab, d)
K_HEAD = "head"              # (d, vocab)
K_PROJ_IN = "proj_in"        # (d_in, d_out): output dim model-sharded (wq/w1)
K_PROJ_OUT = "proj_out"      # (d_in, d_out): input dim model-sharded (wo/w2)
K_EXPERT_IN = "expert_in"    # (E, d, ff)
K_EXPERT_OUT = "expert_out"  # (E, ff, d)
K_VEC_MODEL = "vec_model"    # (n,): per-channel param of a model-sharded dim
K_CONV_MODEL = "conv_model"  # (channels, kwidth): channels model-sharded
K_NORM = "norm"              # replicated small vectors
K_SCALAR = "scalar"
K_REPLICATED = "replicated"  # explicitly replicated projections (small latents)


@dataclasses.dataclass(frozen=True)
class ParamDef:
    """Declarative parameter: shape + dtype + planner kind + init style."""

    shape: tuple
    kind: str
    dtype: object = jnp.float32
    init: str = "normal"       # normal | zeros | ones | scaled
    init_scale: float | None = None   # overrides 1/sqrt(fan_in)

    @property
    def size(self) -> int:
        return int(math.prod(self.shape))


def _divides(dim: int, size: int) -> bool:
    return size > 0 and dim % size == 0


@dataclasses.dataclass
class Planner:
    """Maps ParamDefs and activations to PartitionSpecs on a mesh."""

    mesh: Mesh
    model_axis: str = "model"
    fsdp: bool = False
    # extra layer stacked as a leading scan dimension ('blocks', L, ...)
    stacked: bool = True
    # node-group size 1 (paper C2): pure data parallelism over EVERY mesh
    # axis; the model axis joins the batch axes and parameters are only
    # sharded ZeRO-style (requires fsdp for anything big).
    dp_only: bool = False
    # executed hybrid parallelism (plan_hybrid): `model_paths(path) -> bool`
    # restricts model-axis sharding to parameters of layers the per-layer
    # C2C verdict sends model-parallel; `hybrid` carries the HybridPlan the
    # specs were derived from (the trainer keys its manual axes off it).
    model_paths: Callable[[tuple], bool] | None = None
    hybrid: "HybridPlan | None" = None

    def __post_init__(self):
        names = tuple(self.mesh.axis_names)
        if self.dp_only:
            self.batch_axes = names
            self.model_size = 1
        else:
            self.batch_axes = tuple(a for a in names if a != self.model_axis)
            self.model_size = (self.mesh.shape[self.model_axis]
                               if self.model_axis in names else 1)
        self.batch_size_total = 1
        for a in self.batch_axes:
            self.batch_size_total *= self.mesh.shape[a]

    # -- parameters -----------------------------------------------------------

    def spec_for(self, pd: ParamDef, *, stacked: bool = False,
                 model_ok: bool = True) -> P:
        """PartitionSpec for a parameter (optionally with a leading scan dim).

        `model_ok=False` suppresses model-axis sharding for this parameter
        (the per-layer hybrid plan's DP-fallback layers stay replicated)."""
        dims = [None] * len(pd.shape)
        offset = 1 if stacked else 0     # leading (L, ...) scan dim: replicated
        shape = pd.shape[offset:] if stacked else pd.shape
        kind = pd.kind

        def try_model(cands):
            if self.dp_only or not model_ok:
                return None
            for d in cands:
                if _divides(shape[d], self.model_size):
                    dims[d + offset] = self.model_axis
                    return d
            return None

        def try_fsdp(cands, taken):
            if not self.fsdp:
                return
            for d in cands:
                if d == taken:
                    continue
                for axes in (self.batch_axes, self.batch_axes[-1:]):
                    sz = 1
                    for a in axes:
                        sz *= self.mesh.shape[a]
                    if _divides(shape[d], sz) and shape[d] >= 2 * sz:
                        dims[d + offset] = axes if len(axes) > 1 else axes[0]
                        return

        if kind in (K_NORM, K_SCALAR, K_REPLICATED):
            pass
        elif kind == K_EMBED:
            taken = try_model([0, 1])
            try_fsdp([1, 0], taken)
        elif kind == K_HEAD:
            taken = try_model([1, 0])
            try_fsdp([0, 1], taken)
        elif kind == K_PROJ_IN:
            taken = try_model([len(shape) - 1])
            try_fsdp([0], taken)
        elif kind == K_PROJ_OUT:
            taken = try_model([0])
            try_fsdp([len(shape) - 1], taken)
        elif kind == K_EXPERT_IN:        # (E, d, ff)
            taken = try_model([0, 2])
            try_fsdp([1], taken)
        elif kind == K_EXPERT_OUT:       # (E, ff, d)
            taken = try_model([0, 1])
            try_fsdp([2], taken)
        elif kind == K_VEC_MODEL:
            try_model([0])
        elif kind == K_CONV_MODEL:
            taken = try_model([0])
            del taken
        else:
            raise ValueError(f"unknown param kind {kind!r}")
        return P(*dims)

    def sharding_for(self, pd: ParamDef, *, stacked: bool = False) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec_for(pd, stacked=stacked))

    # -- activations ----------------------------------------------------------

    def batch_spec_axes(self, batch: int):
        """Largest batch-axis group that evenly divides `batch`."""
        for axes in (self.batch_axes, self.batch_axes[-1:], ()):
            sz = 1
            for a in axes:
                sz *= self.mesh.shape[a]
            if axes == () or _divides(batch, sz):
                return axes
        return ()

    def tokens_spec(self, batch: int, extra_dims: int = 1) -> P:
        axes = self.batch_spec_axes(batch)
        lead = axes if len(axes) > 1 else (axes[0] if axes else None)
        return P(lead, *([None] * extra_dims))

    def logits_spec(self, batch: int, vocab: int) -> P:
        axes = self.batch_spec_axes(batch)
        lead = axes if len(axes) > 1 else (axes[0] if axes else None)
        v = self.model_axis if _divides(vocab, self.model_size) else None
        return P(lead, None, v)

    def kv_cache_spec(self, batch: int, seq: int, n_kv: int) -> P:
        """(B, S, n_kv, head_dim) cache: batch over data axes; if the KV-head
        count does not split over the model axis, shard the sequence instead
        (distributed 'flash-decoding' layout)."""
        axes = self.batch_spec_axes(batch)
        lead = axes if len(axes) > 1 else (axes[0] if axes else None)
        if self.dp_only:
            return P(lead, None, None, None)
        if _divides(n_kv, self.model_size):
            return P(lead, None, self.model_axis, None)
        if _divides(seq, self.model_size):
            return P(lead, self.model_axis, None, None)
        return P(lead, None, None, None)

    def state_spec(self, batch: int, dim: int) -> P:
        """(B, dim, ...) recurrent state: dim over model if divisible."""
        axes = self.batch_spec_axes(batch)
        lead = axes if len(axes) > 1 else (axes[0] if axes else None)
        d = self.model_axis if _divides(dim, self.model_size) else None
        return P(lead, d)

    # -- trees ----------------------------------------------------------------

    def tree_specs(self, defs_tree, *, stacked_paths: Callable[[tuple], bool] | None = None):
        """ParamDef tree -> PartitionSpec tree. `stacked_paths(path)` marks
        subtrees whose leaves carry a leading (L,) scan dimension."""
        def one(path, pd):
            st = stacked_paths(path) if stacked_paths else False
            ok = self.model_paths(path) if self.model_paths else True
            return self.spec_for(pd, stacked=st, model_ok=ok)
        return jax.tree_util.tree_map_with_path(
            one, defs_tree, is_leaf=lambda x: isinstance(x, ParamDef))

    def tree_shardings(self, defs_tree, **kw):
        specs = self.tree_specs(defs_tree, **kw)
        return jax.tree_util.tree_map(lambda s: NamedSharding(self.mesh, s),
                                      specs)


def decide_fsdp(n_params: float, model_size: int, *, train: bool = True,
                bytes_per_param_state: float = 14.0,
                hbm_budget: float = 16e9, frac: float = 0.55) -> bool:
    """Should parameters/optimizer state also shard over the batch axes?

    Replicated-across-groups footprint = N * state_bytes / model_group_size;
    enable FSDP when that exceeds `frac` of per-chip HBM.
    """
    bpp = bytes_per_param_state if train else 2.0
    return (n_params * bpp / max(model_size, 1)) > frac * hbm_budget


def make_planner(mesh: Mesh, n_params: float, *, train: bool = True,
                 bytes_per_param_state: float = 14.0,
                 hbm_budget: float = 16e9) -> Planner:
    model_size = mesh.shape.get("model", 1)
    fsdp = decide_fsdp(n_params, model_size, train=train,
                       bytes_per_param_state=bytes_per_param_state,
                       hbm_budget=hbm_budget)
    return Planner(mesh=mesh, fsdp=fsdp)


# --- flat vs hierarchical collective choice (machine-hierarchy planning) -----

ALGO_FLAT = "flat"
ALGO_HIER = "hier"


def bucket_allreduce_times(buckets, algos, nodes: int, topo: hw.Topology, *,
                           bytes_per_elem: float = 4.0, wire: str = "fp32",
                           ef: bool = False,
                           fused_quant: bool = True) -> tuple:
    """Per-bucket allreduce service time under each bucket's routed
    algorithm (ALGO_FLAT rings over all ranks, ALGO_HIER two-level).

    `buckets` is a scheduler.BucketPlan's bucket tuple (anything with
    ``n_elems``); `algos` the matching route tuple (e.g. an
    engine.EnginePlan's ``algos``). `wire`/`ef`/`fused_quant` charge the
    int8 wire's quantization-overhead term (hw.quant_overhead_time)."""
    out = []
    for b, algo in zip(buckets, algos):
        nbytes = b.n_elems * bytes_per_elem
        t = (hw.hier_allreduce_time(nbytes, nodes, topo, wire_inter=wire,
                                    ef=ef, fused_quant=fused_quant)
             if algo == ALGO_HIER else
             hw.flat_allreduce_time(nbytes, nodes, topo, wire=wire, ef=ef,
                                    fused_quant=fused_quant))
        out.append(t)
    return tuple(out)


def estimate_overlap(buckets, algos, nodes: int, topo: hw.Topology,
                     n_micro: int, micro_compute: float, *,
                     bytes_per_elem: float = 4.0):
    """Overlap-aware schedule estimate for an engine bucket plan.

    Returns (blocking_stats, overlap_stats) — simulator.BucketScheduleStats
    for the engine's per-microbatch exchange with and without pipelining,
    using the per-level cost model for each bucket's service time. This is
    the modeled side of bench_overlap's modeled-vs-measured comparison.
    """
    from repro.core import simulator as sim
    times = bucket_allreduce_times(buckets, algos, nodes, topo,
                                   bytes_per_elem=bytes_per_elem)
    off = sim.simulate_bucket_schedule(times, n_micro, micro_compute,
                                       overlap=False)
    on = sim.simulate_bucket_schedule(times, n_micro, micro_compute,
                                      overlap=True)
    return off, on


def choose_allreduce_algo(nbytes: float, nodes: int, topo: hw.Topology,
                          fault=None, *, wire: str = "fp32",
                          ef: bool = False, fused_quant: bool = True) -> str:
    """Pick flat vs two-level allreduce for one message from the per-level
    bandwidth/latency model (repro.core.hw).

    The hierarchy wins when the fabric-volume saving (1/local_size of the
    bytes cross the slow link) beats the two extra intra-node phases; when
    the intra transport is the slower path (virtualized cloud stacks,
    hw.CLOUD_VIRT) bulk messages can legitimately route flat. The bucket
    scheduler applies this per fused message (scheduler.route_buckets), and
    the trainer routes each bucket through it when
    `CommConfig(hier=True, topo=...)` names a topology.

    `fault` (simulator.FaultSpec) composes injected degradation onto the
    topology before costing, so routing re-plans under the degraded model
    — e.g. a congested inter fabric shifts the flat/hier crossover and
    re-routes bulk buckets onto the hierarchy.

    `wire`/`ef`/`fused_quant` add the int8 wire's quantization-overhead
    term to both candidates (the hierarchy quantizes only the fabric shard,
    the flat ring the full message), so routing sees the transform cost --
    and the fusion win -- not just the wire bytes.
    """
    if topo.local_size <= 1 or nodes <= 1:
        return ALGO_FLAT
    if fault is not None:
        topo = fault.apply_to_topology(topo)
    t_flat = hw.flat_allreduce_time(nbytes, nodes, topo, wire=wire, ef=ef,
                                    fused_quant=fused_quant)
    t_hier = hw.hier_allreduce_time(nbytes, nodes, topo, wire_inter=wire,
                                    ef=ef, fused_quant=fused_quant)
    return ALGO_HIER if t_hier < t_flat else ALGO_FLAT


# --- executed hybrid parallelism: C2C verdict -> per-layer sharding ----------

# Block kinds whose parameters the executed tensor-parallel path can shard
# (attention heads / MLP hidden features over the model axis); every other
# kind falls back to data parallelism regardless of the chooser's verdict.
TP_KINDS = ("attn", "local")


def _block_kind(name: str) -> str | None:
    """`p{i}_{kind}` / `t{i}_{kind}` param-tree key -> block kind."""
    if "_" in name and name[0] in ("p", "t"):
        head, kind = name.split("_", 1)
        if head[1:].isdigit():
            return kind
    return None


@dataclasses.dataclass(frozen=True)
class HybridLayerPlan:
    """One layer's C2C verdict plus what actually executes."""

    name: str                  # param-tree key (c2c.layers_from_model_config)
    kind: str                  # block kind (or "embed"/"head")
    choice: c2c.StrategyChoice
    executed: str              # c2c.Strategy value: "model" or "data"
    reason: str = ""           # why executed != the chooser's pick ("": agrees)

    @property
    def model_parallel(self) -> bool:
        return self.executed == c2c.Strategy.MODEL.value


@dataclasses.dataclass(frozen=True)
class HybridPlan:
    """Executable per-layer sharding derived from the C2C chooser.

    Tensor/model parallelism runs over the intra-node `tp_axis` (the model
    group is exactly one node's fast-link domain); data parallelism runs
    across the remaining `data_axes` — the paper's node groups mapped onto
    the machine hierarchy."""

    tp_axis: str
    tp: int                    # model-group size (mesh.shape[tp_axis])
    dp: int                    # number of data-parallel groups
    data_axes: tuple
    layers: tuple              # HybridLayerPlan per c2c layer

    @property
    def model_layer_names(self) -> frozenset:
        return frozenset(l.name for l in self.layers if l.model_parallel)

    @property
    def any_model_parallel(self) -> bool:
        return bool(self.model_layer_names)

    def layer(self, name: str) -> HybridLayerPlan:
        for l in self.layers:
            if l.name == name:
                return l
        raise KeyError(name)

    def param_filter(self) -> Callable[[tuple], bool]:
        """Path predicate for Planner.model_paths: True exactly for the
        parameters of layers this plan executes model-parallel."""
        names = self.model_layer_names

        def ok(path) -> bool:
            return any(getattr(k, "key", None) in names for k in path)
        return ok


def _tp_divisible(cfg, kind: str, tp: int) -> tuple[bool, str]:
    if kind not in TP_KINDS:
        return False, f"unsupported-kind:{kind}"
    a = cfg.attn
    if a.n_heads % tp or a.n_kv % tp:
        return False, f"indivisible-heads:{a.n_heads}q/{a.n_kv}kv%{tp}"
    if cfg.d_ff % tp:
        return False, f"indivisible-ff:{cfg.d_ff}%{tp}"
    return True, ""


def plan_hybrid(cfg, mesh, batch: int, seq: int, *, tp_axis: str = "local",
                group_size: int | None = None,
                bytes_per_elem: float = 4.0) -> HybridPlan:
    """Run the C2C chooser per layer and gate each verdict on executability.

    The chooser is evaluated at the candidate group sizes {1, g} (g defaults
    to the `tp_axis` size; an invalid g contributes ratio 0). A layer
    executes model-parallel IFF the chooser picked the group AND (a) the
    group tiles the `tp_axis` exactly and (b) the layer's head / KV-head /
    hidden-feature counts divide by it — otherwise it cleanly falls back to
    data parallelism with the reason recorded on the layer plan."""
    names = tuple(mesh.axis_names)
    if tp_axis not in names:
        raise ValueError(f"mesh has no {tp_axis!r} axis (axes: {names})")
    tp = int(mesh.shape[tp_axis])
    data_axes = tuple(a for a in names if a != tp_axis)
    dp = 1
    for a in data_axes:
        dp *= int(mesh.shape[a])
    p = dp * tp
    g = tp if group_size is None else group_size
    group_ok = (g == tp)
    group_reason = "" if group_ok else (
        f"group-indivisible:g={g} must equal the {tp_axis!r} axis size {tp}")
    plans = []
    for spec in c2c.layers_from_model_config(cfg, seq):
        choice = c2c.choose_strategy(spec, batch, p,
                                     group_sizes=sorted({1, g}),
                                     bytes_per_elem=bytes_per_elem)
        kind = _block_kind(spec.name) or spec.name
        executed, reason = c2c.Strategy.DATA.value, ""
        if choice.group_size > 1:
            if not group_ok:
                reason = group_reason
            else:
                ok, reason = _tp_divisible(cfg, kind, tp)
                if ok:
                    executed = c2c.Strategy.MODEL.value
        else:
            reason = group_reason if not group_ok else "chooser-data"
        plans.append(HybridLayerPlan(name=spec.name, kind=kind, choice=choice,
                                     executed=executed, reason=reason))
    return HybridPlan(tp_axis=tp_axis, tp=tp, dp=dp, data_axes=data_axes,
                      layers=tuple(plans))


def make_hybrid_planner(mesh, cfg, batch: int, seq: int, *,
                        tp_axis: str = "local",
                        group_size: int | None = None) -> Planner:
    """Planner wired to an executed HybridPlan: parameters shard over
    `tp_axis` only for the layers the (divisibility-gated) C2C chooser
    sends model-parallel; everything else stays replicated and reduces over
    the data axes."""
    plan = plan_hybrid(cfg, mesh, batch, seq, tp_axis=tp_axis,
                       group_size=group_size)
    return Planner(mesh=mesh, model_axis=tp_axis,
                   model_paths=plan.param_filter(), hybrid=plan)


@dataclasses.dataclass(frozen=True)
class HybridCommModel:
    """Modeled per-iteration exposed communication: executed hybrid vs DP."""

    t_dp_flat: float           # pure DP, flat ring over all ranks (fabric)
    t_dp_hier: float           # pure DP routed through the two-level path
    t_hybrid: float            # grads (replicated hier + sharded node ring)
                               #   + activation psums on the intra link
    t_hybrid_grads: float
    t_hybrid_acts: float
    dp_grad_bytes: float       # full-gradient bytes (both DP schedules)
    hybrid_grad_bytes: float   # fabric bytes per local rank under hybrid
    hybrid_act_bytes: float    # intra-link bytes per rank (fwd + bwd psums)

    @property
    def reduction_vs_flat(self) -> float:
        return self.t_dp_flat / self.t_hybrid if self.t_hybrid > 0 else math.inf

    @property
    def reduction_vs_hier(self) -> float:
        return self.t_dp_hier / self.t_hybrid if self.t_hybrid > 0 else math.inf


def model_hybrid_comm(plan: HybridPlan, layers: Sequence[c2c.LayerSpec],
                      batch: int, nodes: int, topo: hw.Topology, *,
                      bytes_per_elem: float = 4.0) -> HybridCommModel:
    """Cost the executed hybrid schedule against pure DP on `topo`.

    Mirrors the engine's executed structure: replicated-parameter gradients
    reduce two-level over (node, local); model-sharded gradients reduce as
    per-local-rank rings over the node axis only (each rank moves its own
    1/tp shard — the factor-tp fabric-volume saving is the hybrid win);
    activations psum over the tp group on the intra link, twice per
    model-parallel layer (forward combine + backward replicate-grad).
    Uses the same hw.*_allreduce_time cost model the bucket router uses."""
    by_name = {l.name: l for l in layers}
    w_rep = w_model = 0.0
    act_t = act_bytes = 0.0
    local_batch = batch / max(nodes, 1)
    for lp in plan.layers:
        spec = by_name[lp.name]
        if lp.model_parallel:
            w_model += spec.weight_elems
            ab = spec.out_elems_per_sample * local_batch * bytes_per_elem
            act_bytes += 2.0 * ab
            act_t += 2.0 * hw.ring_allreduce_time(ab, plan.tp,
                                                  topo.effective_intra)
        else:
            w_rep += spec.weight_elems
    total_bytes = (w_rep + w_model) * bytes_per_elem
    t_dp_flat = hw.flat_allreduce_time(total_bytes, nodes, topo)
    t_dp_hier = hw.hier_allreduce_time(total_bytes, nodes, topo)
    grads_t = hw.hier_allreduce_time(w_rep * bytes_per_elem, nodes, topo) \
        if w_rep else 0.0
    shard_bytes = w_model * bytes_per_elem / max(plan.tp, 1)
    if w_model and nodes > 1:
        grads_t += hw.ring_allreduce_time(shard_bytes, nodes,
                                          topo.effective_inter)
    return HybridCommModel(
        t_dp_flat=t_dp_flat, t_dp_hier=t_dp_hier,
        t_hybrid=grads_t + act_t, t_hybrid_grads=grads_t, t_hybrid_acts=act_t,
        dp_grad_bytes=total_bytes,
        hybrid_grad_bytes=w_rep * bytes_per_elem + shard_bytes,
        hybrid_act_bytes=act_bytes)


# --- the per-layer strategy report (the paper's Table-1-style view) ----------

@dataclasses.dataclass(frozen=True)
class LayerPlan:
    name: str
    kind: str
    choice: c2c.StrategyChoice


def plan_report(layers: Sequence[c2c.LayerSpec], batch: int, p: int,
                group_sizes: Sequence[int] | None = None):
    """Run the C2C chooser over a layer list — what MLSL's DL Layer API would
    decide for each layer of the network on p nodes."""
    report = []
    for l in layers:
        choice = c2c.choose_strategy(l, batch, p, group_sizes=group_sizes)
        report.append(LayerPlan(name=l.name, kind=l.kind.value, choice=choice))
    return report
