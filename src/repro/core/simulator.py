"""Discrete-event simulator of synchronous-SGD communication scheduling.

This is how we validate the paper's *quantitative* claims on a CPU-only
container: the simulator models one training iteration's backward pass, the
gradient allreduce traffic it generates, and the next forward pass that
consumes the reduced gradients, under three network-scheduling policies:

  * BLOCKING        -- allreduce synchronously at each layer boundary
                       (no overlap at all; the naive baseline).
  * FIFO_OVERLAP    -- asynchronous allreduce, serviced in issue order
                       (backprop issues last-layer gradients first, so the
                       first layer's small, urgent reduction queues behind
                       all the bulk transfers -- MPI semantics).
  * PRIORITY_OVERLAP-- MLSL's message prioritization: the network always
                       services the ready transfer needed EARLIEST in the
                       next forward pass, preempting bulk transfers
                       (preempted transfers keep their progress).

The paper reports message prioritization cutting *exposed* communication time
by 1.8x-2.2x on ResNet-50 / VGG-16 / GoogleNet over 10 GbE;
benchmarks/bench_prioritization.py reproduces that with the layer tables in
repro/configs/cnn_tables.py, and bench_scaling.py reproduces Fig. 2's ~90%
scaling efficiency at 256 nodes on Omni-Path.
"""

from __future__ import annotations

import bisect
import dataclasses
import enum
from typing import Sequence

from repro.core import hw


class Policy(str, enum.Enum):
    BLOCKING = "blocking"
    FIFO_OVERLAP = "fifo"
    PRIORITY_OVERLAP = "priority"


@dataclasses.dataclass(frozen=True)
class SimLayer:
    """One layer as the simulator sees it.

    fwd_time / bwd_time are seconds of compute on one node; wgrad_bytes is
    the full (unsharded) weight-gradient size in bytes.
    """

    name: str
    fwd_time: float
    bwd_time: float
    wgrad_bytes: float


@dataclasses.dataclass(frozen=True)
class SimSpan:
    """One interval of the modeled timeline (``record_timeline=True``).

    Times are seconds from iteration start. ``cat`` is "compute" (fwd/bwd
    work), "comm" (the network servicing a transfer — a preempted priority
    transfer yields one span per serviced segment), or "stall" (compute
    waiting on an unfinished allreduce — the exposed time, per layer).
    ``obs.trace.export_sim_spans`` turns these into Chrome-trace events.
    """

    name: str
    cat: str                    # "compute" | "comm" | "stall"
    start: float
    end: float
    layer: int = -1

    @property
    def duration(self) -> float:
        return self.end - self.start


@dataclasses.dataclass
class IterationStats:
    policy: Policy
    total_time: float
    compute_time: float
    exposed_comm: float
    comm_busy: float            # seconds the link was transferring
    completion_times: list     # allreduce completion per layer index
    timeline: list             # SimSpan intervals (record_timeline=True)


@dataclasses.dataclass(frozen=True)
class _Job:
    layer: int
    ready: float
    duration: float


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """Injected degradation for a simulated iteration (the paper's
    Cloud-vs-HPC story off the happy path; Keuper & Pfreundt 1609.06870).

    * ``straggler_slowdown`` (>= 1): the slowest node's compute runs this
      much slower. Synchronous SGD is paced by the critical-path node, so
      the simulator scales the modeled compute timeline by it; the wait is
      accounted as EXPOSED time (``compute_time`` stays the healthy value),
      since cycles spent waiting on a straggler buy no useful work.
      ``straggler_node`` optionally names which node (metadata only — the
      single-server model tracks the critical path, not identities).
    * ``inter_bw_factor`` / ``inter_latency_factor``: degraded inter-node
      fabric (congestion, oversubscription). Without a topology these apply
      to the bare ``link``, which *is* the fabric.
    * ``intra_bw_factor`` / ``intra_latency_factor``: degraded intra-node
      transport (shared-memory pressure, virtio stack contention).
    * ``hetero_link_bw_factors``: per-link bandwidth factors of a
      heterogeneous fabric; a ring is paced by its slowest link, so the
      minimum composes into the effective fabric bandwidth.
    """

    straggler_slowdown: float = 1.0
    straggler_node: int | None = None
    inter_bw_factor: float = 1.0
    inter_latency_factor: float = 1.0
    intra_bw_factor: float = 1.0
    intra_latency_factor: float = 1.0
    hetero_link_bw_factors: tuple = ()

    @property
    def worst_inter_bw_factor(self) -> float:
        worst = min(self.hetero_link_bw_factors, default=1.0)
        return min(self.inter_bw_factor, worst)

    def apply_to_link(self, link: hw.Link) -> hw.Link:
        """Degrade a bare fabric link (the no-topology case)."""
        return hw.LinkDegradation(
            bw_factor=self.worst_inter_bw_factor,
            latency_factor=self.inter_latency_factor).apply(link)

    def apply_to_topology(self, topo: hw.Topology) -> hw.Topology:
        """Compose this fault onto a (possibly already degraded) topology."""
        return topo.degrade(intra_bw=self.intra_bw_factor,
                            intra_latency=self.intra_latency_factor,
                            inter_bw=self.worst_inter_bw_factor,
                            inter_latency=self.inter_latency_factor,
                            straggler=self.straggler_slowdown)

    @property
    def compute_slowdown(self) -> float:
        return max(self.straggler_slowdown, 1.0)


HEALTHY_FAULT = FaultSpec()


def _allreduce_durations(layers: Sequence[SimLayer], p: int, link: hw.Link,
                         overlap_eff: float = 1.0,
                         topo: hw.Topology | None = None,
                         comm_algo: str = "auto", wire: str = "fp32",
                         ef: bool = False,
                         fused_quant: bool = True) -> list:
    """Per-layer allreduce service times.

    `overlap_eff` (0 < eta <= 1) models imperfect asynchronous progress:
    transfers overlapped with compute share host resources (progress thread
    cycles, memory bandwidth, PCIe) and achieve only eta of the wire rate --
    the effect MLSL's dedicated progress cores mitigate but do not remove.
    Applied uniformly to both policies, so policy comparisons stay fair.

    With a `topo` (two-level machine hierarchy), `p` counts NODES and each
    layer's time is the flat ring over the fabric, the two-level
    decomposition, or the per-message cost-model choice (`comm_algo` in
    {"flat", "hier", "auto"}) -- how plans weigh hierarchical collectives.
    `wire`/`ef`/`fused_quant` charge the int8 wire's quantization-overhead
    term (hw.quant_overhead_time) on the topology-costed paths.
    """
    if topo is None:
        return [hw.ring_allreduce_time(l.wgrad_bytes, p, link) / overlap_eff
                for l in layers]
    out = []
    for l in layers:
        t_flat = hw.flat_allreduce_time(l.wgrad_bytes, p, topo, wire=wire,
                                        ef=ef, fused_quant=fused_quant)
        t_hier = hw.hier_allreduce_time(l.wgrad_bytes, p, topo,
                                        wire_inter=wire, ef=ef,
                                        fused_quant=fused_quant)
        t = {"flat": t_flat, "hier": t_hier,
             "auto": min(t_flat, t_hier)}[comm_algo]
        out.append(t / overlap_eff)
    return out


def _serve_fifo(jobs: Sequence[_Job]):
    """Single network resource, service in ready (issue) order.

    Returns (done, segments): per-job completion times plus the serviced
    intervals as (job_index, start, end) — FIFO never preempts, so exactly
    one segment per job.
    """
    order = sorted(range(len(jobs)), key=lambda i: (jobs[i].ready, -jobs[i].layer))
    done = [0.0] * len(jobs)
    segments = []
    t = 0.0
    for i in order:
        start = max(t, jobs[i].ready)
        t = start + jobs[i].duration
        done[i] = t
        segments.append((i, start, t))
    return done, segments


def _serve_priority(jobs: Sequence[_Job]):
    """Preemptive priority service: lowest layer index first.

    Event-driven single-server simulation. When a more urgent job becomes
    ready, the in-flight transfer is preempted and resumed later with its
    remaining bytes intact (MLSL 'completes preempted operations in an
    optimal manner as and when they are required').

    Returns (done, segments): per-job completion times plus the serviced
    intervals as (job_index, start, end) — a preempted job contributes one
    segment per serviced stretch.
    """
    n = len(jobs)
    remaining = [j.duration for j in jobs]
    done = [0.0] * n
    segments = []
    arrivals = sorted(range(n), key=lambda i: jobs[i].ready)
    arrived: list = []          # layer-sorted list of not-yet-finished jobs
    t = 0.0
    ai = 0
    finished = 0
    while finished < n:
        # admit everything that has arrived by t
        while ai < n and jobs[arrivals[ai]].ready <= t:
            i = arrivals[ai]
            bisect.insort(arrived, (jobs[i].layer, i))
            ai += 1
        if not arrived:
            t = jobs[arrivals[ai]].ready
            continue
        _, cur = arrived[0]
        # run until completion or the next arrival, whichever is first
        next_arrival = jobs[arrivals[ai]].ready if ai < n else float("inf")
        finish_at = t + remaining[cur]
        if finish_at <= next_arrival:
            segments.append((cur, t, finish_at))
            t = finish_at
            done[cur] = t
            arrived.pop(0)
            finished += 1
        else:
            if next_arrival > t:
                segments.append((cur, t, next_arrival))
            remaining[cur] -= next_arrival - t
            t = next_arrival
    return done, segments


def simulate_iteration(layers: Sequence[SimLayer], p: int, link: hw.Link,
                       policy: Policy = Policy.PRIORITY_OVERLAP,
                       record_timeline: bool = False,
                       overlap_eff: float = 1.0,
                       topo: hw.Topology | None = None,
                       comm_algo: str = "auto",
                       fault: FaultSpec | None = None, wire: str = "fp32",
                       ef: bool = False,
                       fused_quant: bool = True) -> IterationStats:
    """Simulate bwd(iter k) + allreduce + fwd(iter k+1) under a policy.

    Backward runs layers L-1..0; layer i's allreduce becomes ready when its
    bwd completes. The next forward runs layers 0..L-1 and layer i's forward
    cannot start before its allreduce completed (weights must be updated) --
    exactly the dependency structure the paper exploits.

    With `topo`, `p` counts nodes of `topo.local_size` ranks and the
    collectives are costed on the two-level hierarchy (`comm_algo` selects
    flat / hier / per-message auto); `link` is then ignored.

    With a `fault` (FaultSpec), the links are degraded (composed onto
    `topo`'s own degradation factors, or onto the bare `link`) and a
    straggler stretches the compute timeline; `compute_time` stays the
    HEALTHY compute, so straggler wait shows up as exposed time and every
    fault is monotone in both `total_time` and `exposed_comm`.
    """
    n = len(layers)
    compute = sum(l.fwd_time + l.bwd_time for l in layers)
    if fault is not None:
        if topo is not None:
            topo = fault.apply_to_topology(topo)
        else:
            link = fault.apply_to_link(link)
    slow = max(1.0, topo.straggler if topo is not None else 1.0,
               fault.compute_slowdown if fault is not None else 1.0)
    durations = _allreduce_durations(layers, p, link,
                                     overlap_eff=overlap_eff,
                                     topo=topo, comm_algo=comm_algo,
                                     wire=wire, ef=ef,
                                     fused_quant=fused_quant)
    timeline = []

    def span(name, cat, start, end, layer=-1):
        if record_timeline and end > start:
            timeline.append(SimSpan(name=name, cat=cat, start=start,
                                    end=end, layer=layer))

    if policy is Policy.BLOCKING:
        t = 0.0
        done = [0.0] * n
        for i in range(n - 1, -1, -1):
            span(f"bwd:{layers[i].name}", "compute", t,
                 t + layers[i].bwd_time * slow, layer=i)
            t += layers[i].bwd_time * slow
            span(f"allreduce:{layers[i].name}", "comm", t,
                 t + durations[i], layer=i)
            t += durations[i]          # synchronous allreduce, no overlap
            done[i] = t
        for i in range(n):
            span(f"fwd:{layers[i].name}", "compute", t,
                 t + layers[i].fwd_time * slow, layer=i)
            t += layers[i].fwd_time * slow
        total = t
        return IterationStats(policy=policy, total_time=total,
                              compute_time=compute,
                              exposed_comm=total - compute,
                              comm_busy=sum(durations),
                              completion_times=done, timeline=timeline)

    # --- overlapped policies -------------------------------------------------
    t = 0.0
    jobs = []
    for i in range(n - 1, -1, -1):
        span(f"bwd:{layers[i].name}", "compute", t,
             t + layers[i].bwd_time * slow, layer=i)
        t += layers[i].bwd_time * slow
        jobs.append(_Job(layer=i, ready=t, duration=durations[i]))
    bwd_end = t
    jobs = sorted(jobs, key=lambda j: j.layer)
    if policy is Policy.FIFO_OVERLAP:
        done, segments = _serve_fifo(jobs)
    else:
        done, segments = _serve_priority(jobs)
    for ji, start, end in segments:
        span(f"allreduce:{layers[jobs[ji].layer].name}", "comm", start, end,
             layer=jobs[ji].layer)

    t = bwd_end
    for i in range(n):
        # fwd(i) waits on allreduce(i): the wait IS the exposed time
        span(f"stall:{layers[i].name}", "stall", t, done[i], layer=i)
        t = max(t, done[i])
        span(f"fwd:{layers[i].name}", "compute", t,
             t + layers[i].fwd_time * slow, layer=i)
        t += layers[i].fwd_time * slow
    total = t
    return IterationStats(policy=policy, total_time=total,
                          compute_time=compute,
                          exposed_comm=total - compute,
                          comm_busy=sum(durations),
                          completion_times=done, timeline=timeline)


def scaling_efficiency(layers: Sequence[SimLayer], p: int, link: hw.Link,
                       policy: Policy = Policy.PRIORITY_OVERLAP,
                       topo: hw.Topology | None = None,
                       comm_algo: str = "auto",
                       overlap_eff: float = 1.0,
                       fault: FaultSpec | None = None) -> float:
    """Weak-scaling efficiency at p nodes (fixed per-node mini-batch).

    efficiency = compute-only time / simulated iteration time.

    With a `topo`, p counts NODES: a single node still holds
    topo.local_size communicating ranks, so p == 1 is only trivially
    efficient when the whole hierarchy is one rank. With a `fault`,
    straggler wait and degraded links both cut efficiency (the healthy
    compute is the numerator).
    """
    ranks = topo.flat_size(p) if topo is not None else p
    if ranks <= 1 and (fault is None or fault.compute_slowdown <= 1.0):
        return 1.0
    stats = simulate_iteration(layers, p, link, policy, topo=topo,
                               comm_algo=comm_algo, overlap_eff=overlap_eff,
                               fault=fault)
    return stats.compute_time / stats.total_time


def exposed_comm_reduction(layers: Sequence[SimLayer], p: int,
                           link: hw.Link, *,
                           overlap_eff: float = 1.0,
                           topo: hw.Topology | None = None,
                           comm_algo: str = "auto",
                           fault: FaultSpec | None = None) -> float:
    """Paper headline metric: exposed-comm(FIFO) / exposed-comm(PRIORITY).

    Accepts the same knobs as its siblings (`simulate_iteration`,
    `scaling_efficiency`) so the headline can be computed on a hierarchical
    topology, under imperfect async progress, or under injected faults —
    both policies see identical conditions, keeping the ratio fair.
    """
    kw = dict(overlap_eff=overlap_eff, topo=topo, comm_algo=comm_algo,
              fault=fault)
    fifo = simulate_iteration(layers, p, link, Policy.FIFO_OVERLAP, **kw)
    prio = simulate_iteration(layers, p, link, Policy.PRIORITY_OVERLAP, **kw)
    if prio.exposed_comm <= 0:
        return float("inf") if fifo.exposed_comm > 0 else 1.0
    return fifo.exposed_comm / prio.exposed_comm


# --------------------------------------------------------------------------
# Overlap-aware bucket schedule (the CommEngine's microbatch pipeline)
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class BucketScheduleStats:
    """One training step of the engine's per-microbatch exchange."""

    overlap: bool
    n_micro: int
    total_time: float
    compute_time: float          # n_micro * per-microbatch fwd+bwd
    exposed_comm: float          # total - compute
    comm_busy: float             # n_micro * sum(bucket service times)
    timeline: tuple = ()         # SimSpan intervals (record_timeline=True)


def simulate_bucket_schedule(bucket_times: Sequence[float], n_micro: int,
                             micro_compute: float, *, overlap: bool,
                             record_timeline: bool = False
                             ) -> BucketScheduleStats:
    """Estimate one step of the CommEngine's accumulation-scan exchange.

    Mirrors train.trainer exactly: every microbatch's buckets are reduced
    (service times `bucket_times`, one entry per bucket of the EnginePlan);
    with ``overlap=False`` microbatch k+1's compute waits for microbatch k's
    reduction chain (blocking), with ``overlap=True`` the chain is serviced
    by the network (single resource, in priority order) while the next
    microbatches compute, and only the drain past the last microbatch's
    compute is exposed — the modeled counterpart of what
    benchmarks/bench_overlap.py measures on the virtual-device mesh.

    With ``n_micro == 1`` both schedules degrade to reduce-at-end and the
    full chain is exposed, matching the trainer's fallback.

    ``record_timeline=True`` fills ``timeline`` with SimSpan intervals
    (compute per microbatch, comm per bucket message, the end-of-step drain
    as "stall") in the same span format as ``simulate_iteration`` —
    ``obs.trace.export_sim_spans`` renders either.
    """
    comm_per_micro = float(sum(bucket_times))
    compute = n_micro * micro_compute
    timeline = []

    def span(name, cat, start, end, layer=-1):
        if record_timeline and end > start:
            timeline.append(SimSpan(name=name, cat=cat, start=start,
                                    end=end, layer=layer))

    if not overlap or n_micro == 1:
        # blocking: microbatch k+1's compute gates on k's reduction chain
        t = 0.0
        for k in range(n_micro):
            span(f"micro{k}/compute", "compute", t, t + micro_compute)
            t += micro_compute
            for bi, bt in enumerate(bucket_times):
                span(f"micro{k}/bucket{bi}", "comm", t, t + bt, layer=bi)
                t += bt
        total = compute + n_micro * comm_per_micro
    else:
        t_link = 0.0
        for k in range(n_micro):
            span(f"micro{k}/compute", "compute", k * micro_compute,
                 (k + 1) * micro_compute)
            ready = (k + 1) * micro_compute    # bwd of microbatch k done
            for bi, t in enumerate(bucket_times):
                start = max(t_link, ready)
                span(f"micro{k}/bucket{bi}", "comm", start, start + t,
                     layer=bi)
                t_link = start + t
        total = max(compute, t_link)
        # only the chain's drain past the last microbatch's compute is
        # exposed: that wait is the step's stall
        span("drain", "stall", compute, t_link)
    return BucketScheduleStats(overlap=overlap, n_micro=n_micro,
                               total_time=total, compute_time=compute,
                               exposed_comm=total - compute,
                               comm_busy=n_micro * comm_per_micro,
                               timeline=tuple(timeline))


# --------------------------------------------------------------------------
# labeled fault episodes (ground truth for the health monitor, PR 10)
# --------------------------------------------------------------------------

class _DetJitter:
    """Tiny deterministic multiplicative-noise stream (64-bit LCG).

    The detector benchmark gates precision/recall as STABLE ledger metrics,
    so episode noise must be bit-reproducible across hosts and library
    versions — numpy's generator streams are not guaranteed stable across
    numpy releases, a plain LCG on Python ints is.
    """

    _A = 6364136223846793005
    _C = 1442695040888963407
    _M = (1 << 64) - 1

    def __init__(self, seed: int):
        self._s = ((seed ^ 0x9E3779B97F4A7C15) * self._A + self._C) & self._M

    def uniform(self) -> float:
        """One draw in [-1, 1)."""
        self._s = (self._A * self._s + self._C) & self._M
        return (self._s >> 11) / float(1 << 53) * 2.0 - 1.0

    def factor(self, amplitude: float) -> float:
        """A multiplicative jitter factor in [1 - amplitude, 1 + amplitude)."""
        return 1.0 + amplitude * self.uniform()


@dataclasses.dataclass(frozen=True)
class EpisodeSpec:
    """One deterministic simulated fault episode (telemetry ground truth).

    ``label`` names the alarm the health monitor SHOULD raise ("clean" for
    none): the episode generator replays ``n_steps`` of the engine's bucket
    schedule on ``topo_name``, composing ``fault`` onto the topology from
    ``onset`` onward, and emits records in the telemetry schema
    (repro.obs.telemetry) — so the detector consumes one format whether the
    stream came from a live run or from this generator.

    ``sample_every`` mirrors the driver's bucket-replay sampling knob
    (0 disables bucket_times records entirely — the no-sampling regime where
    only the generic ``step_time_drift`` alarm is reachable).
    """

    name: str
    label: str                    # "clean"|"straggler"|"link_degraded"|
                                  # "step_time_drift"
    fault: FaultSpec = HEALTHY_FAULT
    level: str = ""               # expected link level: "inter" | "intra"
    topo_name: str = "cloud-virtio-sriov"
    nodes: int = 16
    n_steps: int = 60
    onset: int = 20
    sample_every: int = 5
    n_micro: int = 4
    micro_compute: float = 0.2    # seconds of healthy compute per microbatch
    overlap: bool = True
    tokens_per_step: float = 8192.0
    jitter: float = 0.02          # multiplicative measurement noise amplitude
    seed: int = 0

    @property
    def true_factor(self) -> float:
        """The injected degradation factor the detector should estimate, in
        ``hw.Topology.degrade`` convention (straggler >= 1, link bw <= 1)."""
        if self.label == "link_degraded" and self.level == "intra":
            return self.fault.intra_bw_factor
        if self.label == "link_degraded":
            return self.fault.worst_inter_bw_factor
        if self.label in ("straggler", "step_time_drift"):
            return self.fault.compute_slowdown
        return 1.0


def bucket_service_times(bucket_bytes: Sequence[float], algos,
                          nodes: int, topo: hw.Topology, *,
                          wire: str = "fp32", ef: bool = False,
                          fused_quant: bool = True) -> list:
    """Per-bucket allreduce seconds under each bucket's routed algorithm —
    the same hw cost calls as planner.bucket_allreduce_times, inlined here
    so the simulator never imports the planner (which lazily imports this
    module)."""
    out = []
    for nbytes, algo in zip(bucket_bytes, algos):
        if algo == "hier":
            out.append(hw.hier_allreduce_time(nbytes, nodes, topo,
                                              wire_inter=wire, ef=ef,
                                              fused_quant=fused_quant))
        else:
            out.append(hw.flat_allreduce_time(nbytes, nodes, topo, wire=wire,
                                              ef=ef, fused_quant=fused_quant))
    return out


def generate_episode(spec: EpisodeSpec, bucket_bytes: Sequence[float],
                     algos: Sequence[str], *, wire: str = "fp32",
                     ef: bool = False, fused_quant: bool = True) -> list:
    """Replay one labeled fault episode; returns telemetry-schema records.

    Each step runs the engine's bucket schedule (simulate_bucket_schedule)
    with per-bucket service times costed on the healthy topology before
    ``spec.onset`` and on ``spec.fault.apply_to_topology(topo)`` after; a
    straggler stretches the per-microbatch compute. Measured values carry a
    small deterministic multiplicative jitter (``_DetJitter``) so the
    detector's robust statistics are exercised, while the stream stays
    bit-reproducible for the gated precision/recall ledger.

    The first record is a ``meta`` dict (schema_version 1) whose ``run``
    block carries the ground-truth label/onset/factor — the benchmark's
    scoring key. ``repro.obs.telemetry.validate_telemetry`` accepts the
    output verbatim (covered by tests/test_detect.py).
    """
    topo = hw.TOPOLOGIES[spec.topo_name]
    jit = _DetJitter(spec.seed)
    healthy = bucket_service_times(bucket_bytes, algos, spec.nodes, topo,
                                    wire=wire, ef=ef, fused_quant=fused_quant)
    degraded_topo = spec.fault.apply_to_topology(topo)
    degraded = bucket_service_times(bucket_bytes, algos, spec.nodes,
                                     degraded_topo, wire=wire, ef=ef,
                                     fused_quant=fused_quant)
    records = [{
        "kind": "meta", "schema_version": 1, "created_unix": 0.0,
        "sample_every": spec.sample_every,
        "run": {"source": "simulator", "episode": spec.name,
                "label": spec.label, "level": spec.level,
                "topo": spec.topo_name, "nodes": spec.nodes,
                "onset": spec.onset, "true_factor": spec.true_factor,
                "n_buckets": len(list(bucket_bytes))},
    }]
    for step in range(spec.n_steps):
        active = step >= spec.onset
        base = degraded if active else healthy
        slow = spec.fault.compute_slowdown if active else 1.0
        times = [t * jit.factor(spec.jitter) for t in base]
        mc = spec.micro_compute * slow * jit.factor(spec.jitter)
        st = simulate_bucket_schedule(times, spec.n_micro, mc,
                                      overlap=spec.overlap)
        if spec.sample_every > 0 and step % spec.sample_every == 0:
            records.append({"kind": "bucket_times", "step": step,
                            "measured": times, "modeled": list(healthy)})
        exposed = (st.exposed_comm / st.total_time
                   if st.total_time > 0 else 0.0)
        records.append({
            "kind": "step", "step": step, "t_step_s": st.total_time,
            "tok_s": (spec.tokens_per_step / st.total_time
                      if st.total_time > 0 else 0.0),
            "exposed_frac": exposed,
        })
    return records


def layers_from_specs(specs, batch_per_node: int, chip: hw.Chip,
                      bytes_per_elem: float = 4.0) -> list:
    """Turn c2c.LayerSpec shapes into SimLayers using a chip compute model."""
    out = []
    eff_flops = chip.peak_flops * chip.sustained_frac
    for s in specs:
        fwd = s.flops_fwd_per_sample * batch_per_node / eff_flops
        bwd = fwd * s.bwd_flops_factor
        out.append(SimLayer(name=s.name, fwd_time=fwd, bwd_time=bwd,
                            wgrad_bytes=s.weight_elems * bytes_per_elem))
    return out
