"""Hierarchical two-level collectives (machine-hierarchy-aware scale-out).

MLSL's core scale-out insight (paper §3, and You et al. 1708.02983) is that
communication must be organized around the machine's hierarchy: chips inside
a node share a cheap high-bandwidth link, nodes talk over an expensive
fabric. A flat ring allreduce over p = nodes x local ranks pushes the full
gradient volume through the slow fabric; the two-level decomposition

    intra-node reduce-scatter  (local axis, fast link, full volume)
    inter-node allreduce       (node axis, slow fabric, volume / local_size)
    intra-node all-gather      (local axis, fast link, full volume)

moves only 1/local_size of the bytes across the fabric, and lets the
DL-specific optimizations be chosen PER LEVEL: the intra legs run at bf16 (or
fp32 for bit-exactness) while the fabric leg can run the int8 block-quantized
wire with optional error feedback (repro.kernels.quant8 via
repro.core.collectives).

Everything here runs INSIDE a shard_map manual region over both axes, same
contract as repro.core.collectives. The cost model the planner/simulator use
to choose flat vs hierarchical lives in repro.core.hw
(``hier_allreduce_time``) and repro.core.planner (``choose_allreduce_algo``).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import collectives as cl

NODE_AXIS = "node"      # inter-node (fabric) mesh axis
LOCAL_AXIS = "local"    # intra-node (high-bandwidth) mesh axis

# Intra-node legs must REDUCE in transit, so only real float wire formats are
# legal there; the lossy int8 wire is gather-only and belongs on the fabric.
INTRA_WIRES = (cl.WIRE_FP32, cl.WIRE_BF16)


@dataclasses.dataclass(frozen=True)
class HierSpec:
    """Axis factoring + per-leg wire precision of a two-level allreduce."""

    node_axis: str = NODE_AXIS
    local_axis: str = LOCAL_AXIS
    wire_intra: str = cl.WIRE_FP32     # reduce-scatter / all-gather legs
    wire_inter: str = cl.WIRE_FP32     # fabric allreduce leg
    error_feedback: bool = False       # int8 fabric leg only
    # quantization-kernel dispatch for the int8 fabric leg: resolved through
    # the single kernels/ops.py policy (kops.wire_backend) -- the CommEngine
    # resolves "auto" once at plan-build time and records the choice.
    backend: str = "auto"
    fused: bool = True                 # single-pass kernels (False: composed)

    def __post_init__(self):
        if self.wire_intra not in INTRA_WIRES:
            raise ValueError(
                f"intra-node wire must be one of {INTRA_WIRES}, got "
                f"{self.wire_intra!r} (int8 is gather-only; use it on the "
                f"inter-node leg)")
        if self.wire_inter not in cl.WIRES:
            raise ValueError(self.wire_inter)
        if self.error_feedback and self.wire_inter != cl.WIRE_INT8:
            raise ValueError("error feedback requires the int8 fabric leg")
        if self.backend not in ("auto", "pallas", "jnp"):
            raise ValueError(
                f"unknown quantization backend {self.backend!r}")


def default_wire_intra(wire_inter: str) -> str:
    """Intra-node legs default to fp32 for a lossless fabric (bit-exactness)
    and bf16 once the fabric leg is lossy anyway. The single source of this
    policy for Comm.allreduce and trainer.CommConfig."""
    return cl.WIRE_FP32 if wire_inter == cl.WIRE_FP32 else cl.WIRE_BF16


def _pad_quantum(local: int, node: int, wire_inter: str) -> int:
    """Flat-message padding so both legs tile evenly.

    The intra scatter needs local | n; the int8 fabric leg additionally needs
    the per-rank shard to be whole (TILE_ROWS x QUANT_BLOCK) quantization
    rows per node rank (see collectives._allreduce_int8), so pad once here
    and the inner allreduce never re-pads.
    """
    if wire_inter == cl.WIRE_INT8:
        return local * node * cl.QUANT_BLOCK * 8
    return local


def hier_allreduce(x: jax.Array, spec: HierSpec = HierSpec(), *,
                   mean: bool = False,
                   acc: jax.Array | None = None) -> jax.Array:
    """Two-level allreduce; shape- and dtype-preserving.

    Equivalent to ``collectives.allreduce(x, (node_axis, local_axis))`` but
    with the fabric leg carrying 1/local_size of the volume and each leg's
    wire precision independently selectable. The int8 fabric leg consumes
    the wire-dtype shard directly (cast folded into the quantize tile --
    no materialized cast copy between the legs). `acc` (f32, x's shape)
    accumulates the reduced result into an existing buffer.
    """
    orig_dtype = x.dtype
    local = cl.axis_size(spec.local_axis)
    node = cl.axis_size(spec.node_axis)
    p = local * node

    wire_dtype = jnp.bfloat16 if spec.wire_intra == cl.WIRE_BF16 \
        else jnp.float32
    flat = x.reshape(-1).astype(wire_dtype)
    flat = cl._pad_flat(flat, _pad_quantum(local, node, spec.wire_inter))

    # leg 1: intra-node reduce-scatter over the fast link
    with jax.named_scope(f"hier/intra_rs_{spec.wire_intra}"):
        shard = lax.psum_scatter(flat, spec.local_axis, scatter_dimension=0,
                                 tiled=True)
    # leg 2: inter-node allreduce over the fabric, 1/local of the volume
    with jax.named_scope(f"hier/inter_allreduce_{spec.wire_inter}"):
        shard = cl.allreduce(shard, (spec.node_axis,), wire=spec.wire_inter,
                             backend=spec.backend, fused=spec.fused)
    # leg 3: intra-node all-gather over the fast link
    with jax.named_scope(f"hier/intra_ag_{spec.wire_intra}"):
        out = lax.all_gather(shard, spec.local_axis, axis=0, tiled=True)

    out = out[: x.size].reshape(x.shape).astype(orig_dtype)
    if mean:
        out = out / p
    if acc is not None:
        out = acc.reshape(x.shape) + out
    return out


def hier_allreduce_ef(x: jax.Array, residual: jax.Array,
                      spec: HierSpec = HierSpec(wire_inter=cl.WIRE_INT8,
                                                error_feedback=True), *,
                      mean: bool = False, acc: jax.Array | None = None):
    """Two-level allreduce with error feedback on the int8 fabric leg.

    ``residual`` has shape ``ef_residual_shape(x.size, local, node)`` -- the
    per-rank quantization error of this rank's fabric shard, carried into the
    next call (1-bit-SGD style unbiasing, applied only where the lossy wire
    is: the fabric). The fabric leg runs the fused quantize+error-feedback
    kernel per `spec.backend`/`spec.fused`. Returns (reduced, new_residual).
    """
    assert spec.wire_inter == cl.WIRE_INT8, spec
    orig_dtype = x.dtype
    local = cl.axis_size(spec.local_axis)
    node = cl.axis_size(spec.node_axis)
    p = local * node

    wire_dtype = jnp.bfloat16 if spec.wire_intra == cl.WIRE_BF16 \
        else jnp.float32
    flat = x.reshape(-1).astype(wire_dtype)
    flat = cl._pad_flat(flat, _pad_quantum(local, node, spec.wire_inter))

    with jax.named_scope(f"hier/intra_rs_{spec.wire_intra}"):
        shard = lax.psum_scatter(flat, spec.local_axis, scatter_dimension=0,
                                 tiled=True)
    with jax.named_scope("hier/inter_allreduce_int8_ef"):
        shard, new_residual = cl.allreduce_ef(shard, residual,
                                              (spec.node_axis,),
                                              backend=spec.backend,
                                              fused=spec.fused)
    with jax.named_scope(f"hier/intra_ag_{spec.wire_intra}"):
        out = lax.all_gather(shard, spec.local_axis, axis=0, tiled=True)

    out = out[: x.size].reshape(x.shape).astype(orig_dtype)
    if mean:
        out = out / p
    if acc is not None:
        out = acc.reshape(x.shape) + out
    return out, new_residual


def ef_residual_shape(n_elems: int, local: int, node: int) -> tuple:
    """Residual shape for an n_elems bucket on a (node, local) factoring.

    The residual lives on the fabric shard: n padded to the two-level
    quantum, divided by local (intra scatter) and by node (fabric scatter).
    """
    quantum = _pad_quantum(local, node, cl.WIRE_INT8)
    padded = ((n_elems + quantum - 1) // quantum) * quantum
    return (padded // (local * node),)


# --------------------------------------------------------------------------
# Wire-byte accounting (what the fabric actually carries)
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class WireBytes:
    """Amortized bytes one gradient element occupies, split by level."""

    intra: float        # bytes/elem over the intra-node link
    inter: float        # bytes/elem over the inter-node fabric
    total: float


def hier_wire_bytes_per_elem(spec: HierSpec, local: int,
                             node: int) -> WireBytes:
    """Per-element wire bytes of the two-level path, by level.

    Uses the same amortized convention as ``collectives.wire_bytes_per_elem``
    (bytes of the full message per leg, averaged over the two intra legs).
    The fabric leg only carries n/local elements, so its per-element cost is
    the flat wire cost divided by local -- the hierarchy's headline saving.
    """
    isz = 2.0 if spec.wire_intra == cl.WIRE_BF16 else 4.0
    intra = (isz + isz) / 2.0 if local > 1 else 0.0   # RS leg + AG leg
    inter = (cl.wire_bytes_per_elem(spec.wire_inter) / local
             if node > 1 else 0.0)
    return WireBytes(intra=intra, inter=inter, total=intra + inter)


def flat_wire_bytes_per_elem(wire: str) -> WireBytes:
    """Flat single-level allreduce in the same accounting: every byte of the
    message crosses the fabric (the ring spans all p ranks)."""
    b = cl.wire_bytes_per_elem(wire)
    return WireBytes(intra=0.0, inter=b, total=b)
