"""CommEngine: the unified bucket-reduction data path (paper §2, MLSL EP servers).

MLSL puts every performance decision of the gradient exchange — message
fusion, per-message algorithm choice, wire precision, prioritization, and
asynchronous progress — behind one library object so frameworks stay thin.
This module is that object for the reproduction:

  * ``CommConfig``  -- the declarative knobs (mode, wire precision, bucket
    size, error feedback, two-level hierarchy, overlap), shared by the
    trainer, the Session facade, the launch drivers, and the dry-run;
  * ``EnginePlan``  -- the static plan compiled from a gradient structure +
    CommConfig + mesh: bucket boundaries (scheduler.plan_buckets), which
    buckets may travel fused, and each bucket's flat-vs-hierarchical route
    (scheduler.route_buckets over the hw.Topology cost model);
  * ``CommEngine``  -- executes the plan inside a shard_map manual region:
    ``engine.reduce(grads, residuals)`` is the whole exchange, and
    ``engine.reduce_chained`` threads the optimization_barrier token across
    calls so reductions issued from consecutive microbatches form one
    priority chain — the structural analogue of MLSL's endpoint servers
    making progress on microbatch k's buckets while microbatch k+1 computes
    (see train.trainer's overlap mode).

Everything the engine runs must be INSIDE a shard_map manual region over
``data_axes``, same contract as repro.core.collectives / repro.core.hier.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.core import collectives as cl
from repro.core import hier as hier_lib
from repro.core import hw
from repro.core import planner as planner_lib
from repro.core import scheduler
from repro.kernels import ops as kops


@dataclasses.dataclass(frozen=True)
class CommConfig:
    """Declarative communication configuration (consumed by CommEngine and
    the train-step factory; ``train.trainer.CommConfig`` is this class)."""

    mode: str = "gspmd"              # gspmd | mlsl
    wire: str = cl.WIRE_FP32
    prioritize: bool = True
    bucket_bytes: float = 25e6
    error_feedback: bool = False     # int8 wire only
    moe_impl: str = "gather"         # gather | ep  (expert-parallel a2a)
    accum_steps: int = 1             # microbatch gradient accumulation
    kv_chunk: int = 0                # >0: online-softmax attention chunking
    wgather_wire: str = "bf16"       # int8: quantized ZeRO weight gathers (ep)
    kv_dtype: str = "native"         # int8: quantized GQA KV cache (serving)
    # two-level collectives over a ("node", "local") factored data dimension
    # (repro.core.hier): `wire` selects the inter-node fabric leg and
    # `wire_intra` the intra-node legs (None: hier.default_wire_intra).
    # `topo` optionally names a machine hierarchy (repro.core.hw.TOPOLOGIES);
    # when set, each fused bucket is routed flat vs two-level by the
    # per-level cost model (scheduler.route_buckets) instead of always
    # taking the hierarchical path.
    hier: bool = False
    wire_intra: Optional[str] = None
    topo: Optional[str] = None
    # MLSL-style compute/communication overlap (mlsl mode, accum_steps > 1):
    # microbatch k's buckets are reduced interleaved with microbatch k+1's
    # forward/backward inside the accumulation scan. With accum_steps == 1
    # the engine falls back to the single reduce-at-end exchange.
    overlap: bool = False
    # int8 wire kernel dispatch: "auto" resolves through the single
    # kernels/ops.py policy (pallas on TPU, jnp/interpret-validated pallas
    # elsewhere); the resolved choice is recorded in EnginePlan.quant_backend.
    # `fused_quant=False` falls back to the composed (multi-pass) kernels --
    # an ablation/debug path, not a production setting.
    quant_backend: str = "auto"
    fused_quant: bool = True
    # Benchmark ablation: skip gradient reduction entirely. The step then
    # trains on unreduced per-rank gradients (numerically meaningless at
    # dp > 1) — used only to measure the compute-only floor that exposed-
    # communication accounting subtracts (benchmarks/bench_overlap.py).
    skip_reduce: bool = False


@dataclasses.dataclass(frozen=True)
class EnginePlan:
    """Static description of one model's gradient exchange.

    Built once from the (abstract) gradient structure; everything traced at
    step time just walks these tuples.
    """

    buckets: scheduler.BucketPlan
    algos: tuple                     # planner.ALGO_FLAT|ALGO_HIER per bucket
    fusable: tuple                   # bool per bucket: may travel flattened
    data_axes: tuple
    dp: int                          # total data-parallel ranks
    wire: str
    prioritize: bool
    use_ef: bool
    hier_spec: Optional[hier_lib.HierSpec]
    n_node: int                      # 1 when not hierarchical
    n_local: int
    overlap: bool
    accum_steps: int
    skip_reduce: bool = False
    # hybrid (data x model) execution: gradients of model-sharded parameters
    # reduce over the data axes only (each rank owns a distinct 1/tp shard),
    # while replicated-parameter gradients reduce over data axes + tp_axis
    # (their per-rank copies are identical, so the mean is unchanged and the
    # two-level path gets the intra link back). bucket_axes records the
    # reduce axes per bucket; () means "use data_axes for every bucket".
    tp_axis: Optional[str] = None
    tp: int = 1
    bucket_axes: tuple = ()
    # int8 wire execution detail, resolved once at plan-build time: which
    # kernel backend every quantized leg runs ("pallas" | "jnp"), whether the
    # single-pass fused kernels are used, and the per-bucket padding waste
    # fraction the (TILE_ROWS x QUANT_BLOCK) tiling charges (only non-trivial
    # for tiny buckets; () when the wire is not int8).
    quant_backend: str = "jnp"
    fused_quant: bool = True
    quant_pad: tuple = ()
    # the hw.TOPOLOGIES name the buckets were routed against (None when no
    # cost-model routing was requested) — kept on the plan so observability
    # reports (repro.obs.stats) model time on the same topology
    topo: Optional[str] = None

    def axes_for(self, bi: int) -> tuple:
        return self.bucket_axes[bi] if self.bucket_axes else self.data_axes

    @property
    def n_buckets(self) -> int:
        return len(self.buckets.buckets)

    def bucket_bytes_list(self, bytes_per_elem: float = 4.0) -> tuple:
        return tuple(b.n_elems * bytes_per_elem for b in self.buckets.buckets)

    def describe(self, *, topo=None) -> str:
        """The MLSL-style per-bucket stats table for this plan (wire bytes
        per leg, route, modeled service time). Lazy import: repro.obs sits
        above core, so the plan only reaches it when a human asks."""
        from repro.obs import stats as obs_stats
        return obs_stats.CommStats.from_plan(self, topo=topo).table()


def build_plan(grad_struct, comm: CommConfig, mesh, data_axes, *,
               layer_index: Callable[[tuple], float] | None = None,
               group_key: Callable[[tuple], object] | None = None,
               leaf_replicated: Callable[[tuple], bool] | None = None,
               tp_axis: Optional[str] = None,
               leaf_sharded: Callable[[tuple], bool] | None = None
               ) -> EnginePlan:
    """Compile CommConfig + gradient structure + mesh into an EnginePlan.

    `grad_struct` is any pytree of arrays/ShapeDtypeStructs with the
    gradients' shapes. `group_key(path)` marks sharding groups that must not
    fuse across; `leaf_replicated(path)` says whether a leaf is fully
    replicated over the auto axes (only such buckets may travel as one flat
    message — flattening a model-sharded gradient would reshard it).

    `tp_axis` + `leaf_sharded` switch on hybrid (data x model) execution:
    the engine then runs inside a manual region over data_axes + tp_axis,
    `grad_struct` describes each rank's LOCAL gradient shards, and
    `leaf_sharded(path)` marks leaves whose parameter is model-sharded over
    `tp_axis`. Sharded buckets reduce over the data axes only; replicated
    buckets reduce over data axes + tp_axis (identical per-rank copies, so
    the mean is unchanged and the hierarchical route stays available). In
    this fully-manual region every leaf is a local array, so all buckets may
    travel fused.
    """
    if layer_index is None:
        layer_index = scheduler.default_layer_index
    plan = scheduler.plan_buckets(grad_struct, layer_index,
                                  bucket_bytes=comm.bucket_bytes,
                                  group_key=group_key)
    leaf_paths = [path for path, _ in
                  jax.tree_util.tree_leaves_with_path(grad_struct)]
    if leaf_replicated is None:
        fusable = tuple(True for _ in plan.buckets)
    else:
        fusable = tuple(
            all(leaf_replicated(leaf_paths[i]) for i in b.leaf_ids)
            for b in plan.buckets)

    dp = 1
    for a in data_axes:
        dp *= mesh.shape[a]
    use_ef = comm.error_feedback and comm.wire == cl.WIRE_INT8
    # resolve the kernel backend ONCE here (the plan records the choice; the
    # traced data path never consults the policy again) and account the
    # tiling pad waste per bucket so undersized int8 buckets are visible
    qb = kops.wire_backend(comm.quant_backend)
    quant_pad = ()
    if comm.wire == cl.WIRE_INT8:
        quant_pad = tuple(kops.pad_info(b.n_elems).waste_frac
                          for b in plan.buckets)

    tp = 1
    bucket_axes = ()
    sharded_buckets = tuple(False for _ in plan.buckets)
    if tp_axis is not None:
        if leaf_sharded is None:
            raise ValueError("tp_axis requires a leaf_sharded predicate")
        if use_ef:
            raise ValueError(
                "error feedback is unsupported with hybrid tensor "
                "parallelism: the int8 residual is a per-rank fabric shard, "
                "but model-sharded gradients reduce over the node axis only "
                "while replicated ones reduce over (node, local)")
        tp = int(mesh.shape[tp_axis])
        sharded_buckets = tuple(
            any(leaf_sharded(leaf_paths[i]) for i in b.leaf_ids)
            for b in plan.buckets)
        full = tuple(data_axes) + (tp_axis,)
        bucket_axes = tuple(tuple(data_axes) if sh else full
                            for sh in sharded_buckets)
        fusable = tuple(True for _ in plan.buckets)

    hier_spec = None
    n_node, n_local = 1, dp
    if comm.hier:
        hier_axes = tuple(data_axes) + ((tp_axis,) if tp_axis else ())
        assert hier_lib.NODE_AXIS in hier_axes and \
            hier_lib.LOCAL_AXIS in hier_axes, (
                "comm.hier needs the data dimension factored over "
                f"({hier_lib.NODE_AXIS!r}, {hier_lib.LOCAL_AXIS!r}) mesh "
                f"axes (launch.mesh.make_hier_mesh); got {hier_axes}")
        wire_intra = comm.wire_intra or hier_lib.default_wire_intra(comm.wire)
        hier_spec = hier_lib.HierSpec(wire_intra=wire_intra,
                                      wire_inter=comm.wire,
                                      error_feedback=use_ef,
                                      backend=qb,
                                      fused=comm.fused_quant)
        n_node = mesh.shape[hier_lib.NODE_AXIS]
        n_local = mesh.shape[hier_lib.LOCAL_AXIS]
        if comm.topo is not None:
            if comm.topo not in hw.TOPOLOGIES:
                raise ValueError(
                    f"unknown topology {comm.topo!r}; known: "
                    f"{sorted(hw.TOPOLOGIES)}")
            # per-bucket flat-vs-two-level routing from the per-level cost
            # model: small latency-bound buckets may stay flat while bulk
            # buckets take the hierarchy (MLSL per-message phase choice)
            algos = scheduler.route_buckets(plan, hw.TOPOLOGIES[comm.topo],
                                            nodes=n_node, wire=comm.wire,
                                            ef=use_ef,
                                            fused_quant=comm.fused_quant)
        else:
            algos = tuple(planner_lib.ALGO_HIER for _ in plan.buckets)
        if tp_axis is not None:
            # the two-level path needs BOTH hierarchy axes in a bucket's
            # reduce axes; model-sharded buckets reduce over the node axis
            # only, so they always go flat
            algos = tuple(planner_lib.ALGO_FLAT if sh else a
                          for a, sh in zip(algos, sharded_buckets))
    else:
        algos = tuple(planner_lib.ALGO_FLAT for _ in plan.buckets)

    return EnginePlan(buckets=plan, algos=algos, fusable=fusable,
                      data_axes=tuple(data_axes), dp=dp, wire=comm.wire,
                      prioritize=comm.prioritize, use_ef=use_ef,
                      hier_spec=hier_spec, n_node=n_node, n_local=n_local,
                      overlap=comm.overlap, accum_steps=comm.accum_steps,
                      skip_reduce=comm.skip_reduce, tp_axis=tp_axis, tp=tp,
                      bucket_axes=bucket_axes, quant_backend=qb,
                      fused_quant=comm.fused_quant, quant_pad=quant_pad,
                      topo=comm.topo)


@dataclasses.dataclass(frozen=True)
class CommEngine:
    """Executes an EnginePlan: the single entry point for bucket reduction."""

    plan: EnginePlan

    @classmethod
    def create(cls, grad_struct, comm: CommConfig, mesh, data_axes,
               **kw) -> "CommEngine":
        return cls(plan=build_plan(grad_struct, comm, mesh, data_axes, **kw))

    @property
    def tp(self) -> Optional[cl.TPComm]:
        """Activation-exchange communicator for the plan's model axis (None
        on pure-DP plans): the f/g operator pair model-parallel layers place
        around their sharded projections (collectives.tp_replicate /
        tp_psum), handed out here so the activation flow and the gradient-
        bucket flow share one comm surface."""
        if self.plan.tp_axis is None:
            return None
        return cl.TPComm(self.plan.tp_axis)

    # -- residual (error-feedback) state -----------------------------------

    def ef_applied(self, bi: int) -> bool:
        """Does bucket `bi` actually run the error-feedback int8 wire?

        Non-fusable (model-sharded) buckets are forced onto the bf16 wire by
        `reduce_chained` and carry their residual entry through unchanged, so
        allocating them a real residual buffer would waste fp32 memory
        proportional to the model's sharded footprint."""
        return self.plan.use_ef and self.plan.fusable[bi]

    def init_residuals(self):
        """Global-view zero residuals: per-rank shard shape x dp ranks (the
        shard_map in_spec splits them back to one fabric shard per rank).

        Only buckets whose data path applies error feedback (fusable ones —
        see `ef_applied`) get real buffers; the rest hold zero-length
        placeholders so the residual tuple keeps one entry per bucket and
        `residual_specs` stays aligned."""
        p = self.plan
        if not p.use_ef:
            return None

        def shard(bi, b):
            if not self.ef_applied(bi):
                return 0
            if p.algos[bi] == planner_lib.ALGO_HIER:
                return hier_lib.ef_residual_shape(b.n_elems, p.n_local,
                                                  p.n_node)[0]
            return cl.ef_residual_shape(b.n_elems, p.dp)[0]

        return tuple(jnp.zeros((shard(bi, b) * p.dp,), jnp.float32)
                     for bi, b in enumerate(p.buckets.buckets))

    def residual_specs(self, bucket_spec):
        """shard_map in/out specs for the residual state (None without EF)."""
        if not self.plan.use_ef:
            return None
        return tuple(bucket_spec for _ in self.plan.buckets.buckets)

    # -- the data path ------------------------------------------------------

    def _reduce_bucket(self, flat, residual, bi: int, acc=None):
        """One fused message over the data axes: flat or two-level path per
        the bucket routing. Returns (reduced, new_residual_or_None).

        `acc` (f32, flat's shape) folds an existing accumulator into the
        gather-side dequantize (kernels.ops.dequantize_accumulate): on the
        int8 wire the sum lands in the same pass that expands the wire
        payload, instead of a separate full-size read-add-write.

        The whole message is wrapped in a `jax.named_scope` so XLA profiles
        attribute device time to the named bucket + route (metadata only —
        numerics and schedules are untouched)."""
        p = self.plan
        route = "hier" if p.algos[bi] == planner_lib.ALGO_HIER else "flat"
        with jax.named_scope(f"bucket{bi}/{route}_allreduce_{p.wire}"):
            if p.algos[bi] == planner_lib.ALGO_HIER:
                if p.use_ef:
                    return hier_lib.hier_allreduce_ef(flat, residual,
                                                      p.hier_spec, mean=True,
                                                      acc=acc)
                return hier_lib.hier_allreduce(flat, p.hier_spec, mean=True,
                                               acc=acc), None
            if p.use_ef:
                return cl.allreduce_ef(flat, residual, p.data_axes,
                                       mean=True, backend=p.quant_backend,
                                       fused=p.fused_quant, acc=acc)
            return cl.allreduce(flat, p.axes_for(bi), wire=p.wire,
                                mean=True, backend=p.quant_backend,
                                fused=p.fused_quant, acc=acc), None

    def reduce_chained(self, grads, residuals, token):
        """Fused, prioritized, wire-precision gradient exchange, continuing
        an existing priority chain.

        Replicated buckets travel as one fused flat message (MLSL message
        fusion + optional int8 block quantization and error feedback).
        Model-sharded buckets are reduced per-leaf, shape-preserving (no
        resharding); the int8 wire's flatten/scatter composition would
        reshard them, so those leaves use the bf16 wire instead.

        `token` is the optimization_barrier chain carried in from a previous
        exchange (or None / a constant scalar to start a fresh chain): with
        prioritization, bucket k+1's message is made data-dependent on bucket
        k's reduced result, so the compiler issues collectives in forward-
        layer order across ALL chained calls — in the trainer's overlap mode
        the chain spans microbatches, ordering microbatch k's reduction ahead
        of microbatch k+1's without tying it to k+1's compute.
        Returns (reduced_tree, new_residuals, token).
        """
        p = self.plan
        if p.skip_reduce:
            return grads, residuals, token
        leaves = jax.tree_util.tree_leaves(grads)
        new_leaves = list(leaves)
        new_residuals = []
        for bi, bucket in enumerate(p.buckets.buckets):
            if p.fusable[bi]:
                flat = scheduler.fuse_bucket(leaves, bucket)
                if p.prioritize:
                    flat, token = scheduler.chain_barrier(flat, token)
                red, res = self._reduce_bucket(
                    flat, residuals[bi] if p.use_ef else None, bi)
                if p.use_ef:
                    new_residuals.append(res)
                if p.prioritize:
                    token = scheduler._token_of(red)
                for lid, leaf in scheduler.unfuse_bucket(red, bucket).items():
                    new_leaves[lid] = leaf
            else:
                vals = [leaves[i] for i in bucket.leaf_ids]
                if p.prioritize:
                    vals, token = scheduler.chain_barrier(vals, token)
                wire = p.wire if p.wire != cl.WIRE_INT8 else cl.WIRE_BF16
                with jax.named_scope(f"bucket{bi}/leafwise_allreduce_{wire}"):
                    vals = [cl.allreduce(v, p.axes_for(bi), wire=wire,
                                         mean=True) for v in vals]
                if p.use_ef:
                    new_residuals.append(residuals[bi])
                if p.prioritize:
                    token = scheduler._token_of(vals[0])
                for lid, leaf in zip(bucket.leaf_ids, vals):
                    new_leaves[lid] = leaf
        out = jax.tree_util.tree_unflatten(p.buckets.treedef, new_leaves)
        return out, (tuple(new_residuals) if p.use_ef else residuals), token

    # -- flat gradient accumulation (microbatch loop) -----------------------
    #
    # The trainer's accumulation loop used to materialize a reduced gradient
    # TREE per microbatch and tree-add it into a sum. With the int8 wire that
    # is a full extra read+write of the model per microbatch. These methods
    # keep the accumulator in the engine's own bucket layout (one flat f32
    # buffer per fused bucket) so the add rides the gather-side
    # dequantize_accumulate pass instead.

    def init_accum(self):
        """Zero accumulators in bucket layout: one flat f32 buffer per
        fusable bucket, a per-leaf f32 tuple for non-fusable ones."""
        p = self.plan
        return tuple(
            jnp.zeros((b.n_elems,), jnp.float32) if p.fusable[bi]
            else tuple(jnp.zeros(shape, jnp.float32) for shape in b.shapes)
            for bi, b in enumerate(p.buckets.buckets))

    def reduce_accum_chained(self, grads, acc, residuals, token):
        """reduce_chained, but the reduced messages land IN the bucket-layout
        accumulator (`acc`, from `init_accum`) instead of coming back as a
        gradient tree: acc'[bi] = acc[bi] + reduce(bucket bi of grads).

        On the int8 wire the accumulate is fused into the gather-side
        dequantize (one pass); on float wires it is a plain add on the
        reduced message (still bucket-sized, never tree-shaped). Returns
        (new_acc, new_residuals, token) — unbucketed via `unfuse_accum`
        after the last microbatch.
        """
        p = self.plan
        leaves = jax.tree_util.tree_leaves(grads)
        new_acc = []
        new_residuals = []
        for bi, bucket in enumerate(p.buckets.buckets):
            if p.fusable[bi]:
                flat = scheduler.fuse_bucket(leaves, bucket)
                if p.skip_reduce:
                    new_acc.append(acc[bi] + flat)
                    if p.use_ef:
                        new_residuals.append(residuals[bi])
                    continue
                if p.prioritize:
                    flat, token = scheduler.chain_barrier(flat, token)
                red, res = self._reduce_bucket(
                    flat, residuals[bi] if p.use_ef else None, bi,
                    acc=acc[bi])
                if p.use_ef:
                    new_residuals.append(res)
                if p.prioritize:
                    token = scheduler._token_of(red)
                new_acc.append(red)
            else:
                vals = [leaves[i] for i in bucket.leaf_ids]
                if p.skip_reduce:
                    new_acc.append(tuple(
                        a + v.astype(jnp.float32)
                        for a, v in zip(acc[bi], vals)))
                    if p.use_ef:
                        new_residuals.append(residuals[bi])
                    continue
                if p.prioritize:
                    vals, token = scheduler.chain_barrier(vals, token)
                wire = p.wire if p.wire != cl.WIRE_INT8 else cl.WIRE_BF16
                with jax.named_scope(f"bucket{bi}/leafwise_allreduce_{wire}"):
                    vals = [cl.allreduce(v, p.axes_for(bi), wire=wire,
                                         mean=True) for v in vals]
                if p.use_ef:
                    new_residuals.append(residuals[bi])
                if p.prioritize:
                    token = scheduler._token_of(vals[0])
                new_acc.append(tuple(
                    a + v.astype(jnp.float32)
                    for a, v in zip(acc[bi], vals)))
        return (tuple(new_acc),
                (tuple(new_residuals) if p.use_ef else residuals), token)

    def unfuse_accum(self, acc):
        """Bucket-layout accumulator -> f32 gradient tree (no dtype cast:
        the trainer divides by accum_steps before casting to param dtype)."""
        p = self.plan
        leaves = [None] * p.buckets.treedef.num_leaves
        for bi, b in enumerate(p.buckets.buckets):
            if p.fusable[bi]:
                off = 0
                for lid, size, shape in zip(b.leaf_ids, b.sizes, b.shapes):
                    leaves[lid] = acc[bi][off:off + size].reshape(shape)
                    off += size
            else:
                for lid, a in zip(b.leaf_ids, acc[bi]):
                    leaves[lid] = a
        return jax.tree_util.tree_unflatten(p.buckets.treedef, leaves)

    def gate_token_accum(self, acc):
        """`gate_token` over a bucket-layout accumulator (blocking schedule:
        gate the next microbatch on every collective having retired)."""
        p = self.plan
        toks = []
        for bi in range(p.n_buckets):
            if p.fusable[bi]:
                toks.append(acc[bi].reshape(-1)[0])
            else:
                toks.extend(a.reshape(-1)[0] for a in acc[bi])
        if not toks:
            return jnp.zeros((), jnp.float32)
        out = toks[0]
        for t in toks[1:]:
            out = out + t
        return out

    def gate_token(self, grads):
        """A scalar data-dependent on EVERY collective of the exchange.

        The trainer's blocking schedule gates the next microbatch's inputs
        on this, so compute cannot start before the whole exchange retires
        even when prioritization (and with it the engine's own token
        threading) is off. A fused bucket is one collective (its first leaf
        covers it); a non-fusable bucket reduces per leaf, so every leaf
        contributes. Returns a zero scalar for an empty plan."""
        leaves = jax.tree_util.tree_leaves(grads)
        toks = []
        for bi, b in enumerate(self.plan.buckets.buckets):
            ids = b.leaf_ids[:1] if self.plan.fusable[bi] else b.leaf_ids
            toks.extend(leaves[i].reshape(-1)[0] for i in ids)
        if not toks:
            return jnp.zeros((), jnp.float32)
        out = toks[0]
        for t in toks[1:]:
            out = out + t
        return out

    def reduce(self, grads, residuals):
        """The whole exchange as one call (fresh priority chain).

        Returns (reduced_tree, new_residuals)."""
        out, residuals, _ = self.reduce_chained(grads, residuals, None)
        return out, residuals

    # -- observability -------------------------------------------------------

    def stats(self, *, measured=None, topo=None):
        """MLSL-style per-message statistics for this engine's plan
        (repro.obs.stats.CommStats): per-bucket wire bytes by leg/level,
        route, modeled service time on `topo` (defaults to the plan's
        routing topology), and — when `measured` (a per-bucket seconds
        sequence, e.g. obs.stats.measure_bucket_times) is given — the
        measured column. Lazy import keeps core independent of obs."""
        from repro.obs import stats as obs_stats
        return obs_stats.CommStats.from_plan(self.plan, measured=measured,
                                             topo=topo)

    def bucket_timer(self, mesh, *, seed: int = 0):
        """Compile-once per-bucket replay of this engine's reduce path
        (repro.obs.stats.BucketTimer). Building it jits one region per
        bucket; each ``sample()`` afterwards is cheap enough for the
        telemetry loop to run between steps every N steps. Lazy import
        keeps core independent of obs."""
        from repro.obs import stats as obs_stats
        return obs_stats.BucketTimer(self, mesh, seed=seed)
