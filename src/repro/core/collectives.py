"""MLSL-style collectives API (the paper's lower-level framework interface).

The paper's library exposes MPI-like collectives but implements the
performance-critical data path itself: asynchronous progress, message
prioritization, and low-precision wire formats. On TPU/JAX the data path is
expressed inside `shard_map` manual regions with `jax.lax` collectives; the
DL-specific optimizations live here:

  * wire-precision selection per collective ("fp32" | "bf16" | "int8"):
    int8 composes reduce_scatter(bf16) -> block-quantize -> all_gather(int8 +
    f32 scales) -> dequantize, cutting gathered wire bytes ~4x vs fp32;
  * optional error-feedback residual for the lossy int8 path;
  * fused/flattened bucket reduction (callers concatenate many small
    gradients into one message -- see repro.core.scheduler).

Everything here assumes it is called INSIDE a shard_map manual region over
`axes` (a name or tuple of names). `Comm.run` wraps a function in such a
region for convenience.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.kernels import ops as kops

WIRE_FP32 = "fp32"
WIRE_BF16 = "bf16"
WIRE_INT8 = "int8"
WIRES = (WIRE_FP32, WIRE_BF16, WIRE_INT8)

QUANT_BLOCK = 512


def wire_bytes_per_elem(wire: str, compute_dtype=jnp.float32) -> float:
    """Bytes that one gradient element occupies on the wire (amortized)."""
    if wire == WIRE_FP32:
        return jnp.dtype(compute_dtype).itemsize
    if wire == WIRE_BF16:
        return 2.0
    if wire == WIRE_INT8:
        # reduce-scatter leg in bf16 (2B/elem over 1 hop-volume) + all-gather
        # leg in int8 (1B/elem) + one f32 scale per QUANT_BLOCK elements.
        return (2.0 + 1.0 + 4.0 / QUANT_BLOCK) / 2.0
    raise ValueError(wire)


def _axes_tuple(axes) -> tuple:
    return (axes,) if isinstance(axes, str) else tuple(axes)


def axis_size(axes) -> int:
    """Product of the manual-axis sizes (callable inside shard_map)."""
    return compat.axis_size(_axes_tuple(axes))


def _pad_flat(flat: jax.Array, quantum: int) -> jax.Array:
    n = flat.shape[0]
    padded = ((n + quantum - 1) // quantum) * quantum
    return jnp.pad(flat, (0, padded - n))


def allreduce(x: jax.Array, axes, *, wire: str = WIRE_FP32,
              mean: bool = False, backend: str = "auto", fused: bool = True,
              acc: jax.Array | None = None) -> jax.Array:
    """Allreduce with a selectable wire precision. Shape-preserving.

    `backend` selects the quantization kernels for the int8 wire and flows
    from the single kernels/ops.py policy (`kops.wire_backend`; the
    CommEngine resolves it once and records it in the EnginePlan). `fused`
    runs the single-pass kernels (set False only to measure the composed
    data path). `acc` (same shape as x, f32) fuses the gather-side
    accumulate: the reduced message is added into `acc` and the sum
    returned -- on the int8 wire via `dequantize_accumulate` so the gathered
    message is consumed in one pass.
    """
    ax = _axes_tuple(axes)
    p = axis_size(ax)
    if wire == WIRE_INT8:
        return _allreduce_int8(x, ax, mean=mean, backend=backend,
                               fused=fused, acc=acc)
    if wire == WIRE_FP32:
        out = lax.psum(x, ax)
    elif wire == WIRE_BF16:
        out = lax.psum(x.astype(jnp.bfloat16), ax).astype(x.dtype)
    else:
        raise ValueError(wire)
    if mean:
        out = out / p
    if acc is not None:
        out = acc.reshape(x.shape) + out
    return out


def _gather_quantized(q: jax.Array, s: jax.Array, ax: tuple):
    for a in reversed(ax):         # gather back in reverse scatter order
        q = lax.all_gather(q, a, axis=0, tiled=True)
        s = lax.all_gather(s, a, axis=0, tiled=True)
    return q, s


def _dequant_full(q, s, meta, n_full: int, *, size: int, shape, out_dtype,
                  mean_div: int, backend: str, acc):
    """Gather-side dequantize of the full (gathered) message.

    The mean is folded into the per-block scale vector (n/QUANT_BLOCK
    elements) instead of dividing the full-size dequantized message -- one
    full HBM pass saved. With `acc`, the dequantize accumulates directly
    into the f32 accumulator (quant8.dequantize_accumulate_blocks), so the
    gathered int8 message is read once and the sum written once."""
    if mean_div > 1:
        s = s / mean_div
    full_meta = dataclasses.replace(meta, shape=(n_full,), n=n_full,
                                    dtype=jnp.float32)
    if acc is not None:
        out = kops.dequantize_accumulate(q, s, acc.reshape(-1), full_meta,
                                         backend=backend)
        return out[:size].reshape(shape)          # stays f32 (acc's dtype)
    deq = kops.dequantize(q, s, full_meta, backend=backend)
    return deq[:size].reshape(shape).astype(out_dtype)


def _allreduce_int8(x: jax.Array, ax: tuple, *, mean: bool = False,
                    backend: str = "auto", fused: bool = True,
                    acc: jax.Array | None = None) -> jax.Array:
    """reduce_scatter(bf16) + quantize + all_gather(int8) + dequantize."""
    orig_dtype = x.dtype
    flat = x.reshape(-1).astype(jnp.bfloat16)
    p = axis_size(ax)
    # shard must be a whole number of (TILE_ROWS x block) quantization rows
    quantum = p * QUANT_BLOCK * 8  # kernels.quant8.TILE_ROWS == 8
    flat = _pad_flat(flat, quantum)
    shard = flat
    for a in ax:                   # sequential scatter over each axis
        shard = lax.psum_scatter(shard, a, scatter_dimension=0, tiled=True)
    if fused:
        # wire cast folded into the quantize tile: the bf16 shard is
        # consumed directly, no materialized f32 copy
        q, s, meta = kops.quantize(shard, block=QUANT_BLOCK, backend=backend)
    else:
        q, s, meta = kops.quantize(shard.astype(jnp.float32),
                                   block=QUANT_BLOCK, backend=backend)
    q, s = _gather_quantized(q, s, ax)
    return _dequant_full(q, s, meta, flat.shape[0], size=x.size,
                         shape=x.shape, out_dtype=orig_dtype,
                         mean_div=p if mean else 1, backend=backend, acc=acc)


def allreduce_ef(x: jax.Array, residual: jax.Array, axes, *,
                 mean: bool = False, backend: str = "auto",
                 fused: bool = True, acc: jax.Array | None = None):
    """int8 allreduce with error feedback.

    `residual` has the shape of this rank's reduce-scatter shard (see
    `ef_residual_shape`); the quantization error of the local shard is
    carried into the next call, making the compression unbiased over time
    (1-bit-SGD / DGC style -- paper refs [5,13,16]).

    The fabric leg reads and writes the gradient shard exactly once per
    direction: quantize-side, `kops.quantize_ef` consumes the bf16 wire
    shard and the f32 residual in one pass (cast + error-feedback add +
    quantize + residual update fused); gather-side, the mean folds into the
    scale vector and `acc` accumulates through `dequantize_accumulate`.
    `fused=False` runs the composed passes (same kernels, separate trips) --
    bit-identical at fp32, kept for the fused-vs-unfused tests/benchmarks.
    Returns (reduced, new_residual).
    """
    orig_dtype = x.dtype
    ax = _axes_tuple(axes)
    p = axis_size(ax)
    flat = x.reshape(-1).astype(jnp.bfloat16)
    quantum = p * QUANT_BLOCK * 8
    flat = _pad_flat(flat, quantum)
    shard = flat
    for a in ax:
        shard = lax.psum_scatter(shard, a, scatter_dimension=0, tiled=True)
    if fused:
        q, s, meta, new_residual = kops.quantize_ef(
            shard, residual, block=QUANT_BLOCK, backend=backend)
    else:
        # composed reference path: separate cast/add, quantize, and
        # residual-update trips; the residual still routes through the
        # fused dequantize_accumulate kernel (y + q * (-s) == y - q * s
        # bitwise), so both paths agree bit-for-bit at fp32
        y = shard.astype(jnp.float32) + residual
        q, s, meta = kops.quantize(y, block=QUANT_BLOCK, backend=backend)
        new_residual = kops.dequantize_accumulate(q, -s, y, meta,
                                                  backend=backend)
    q, s = _gather_quantized(q, s, ax)
    out = _dequant_full(q, s, meta, flat.shape[0], size=x.size,
                        shape=x.shape, out_dtype=orig_dtype,
                        mean_div=p if mean else 1, backend=backend, acc=acc)
    return out, new_residual


def ef_residual_shape(n_elems: int, p: int) -> tuple:
    """Shape of the error-feedback residual for an n_elems bucket on p ranks."""
    quantum = p * QUANT_BLOCK * 8
    padded = ((n_elems + quantum - 1) // quantum) * quantum
    return (padded // p,)


def reduce_scatter(x: jax.Array, axes, *, wire: str = WIRE_FP32) -> jax.Array:
    ax = _axes_tuple(axes)
    y = x.astype(jnp.bfloat16) if wire == WIRE_BF16 else x
    for a in ax:
        y = lax.psum_scatter(y, a, scatter_dimension=0, tiled=True)
    return y.astype(x.dtype)


def all_gather(x: jax.Array, axes, *, axis: int = 0) -> jax.Array:
    y = x
    for a in reversed(_axes_tuple(axes)):
        y = lax.all_gather(y, a, axis=axis, tiled=True)
    return y


def all_to_all(x: jax.Array, axes, *, split_axis: int,
               concat_axis: int) -> jax.Array:
    ax = _axes_tuple(axes)
    assert len(ax) == 1, "all_to_all over a single mesh axis"
    return lax.all_to_all(x, ax[0], split_axis=split_axis,
                          concat_axis=concat_axis, tiled=True)


def broadcast(x: jax.Array, axes, *, root: int = 0) -> jax.Array:
    """Broadcast rank `root`'s value (implemented as masked psum)."""
    ax = _axes_tuple(axes)
    idx = lax.axis_index(ax)
    mask = (idx == root).astype(x.dtype)
    return lax.psum(x * mask, ax)


# --- activation exchange for tensor/model parallelism (hybrid execution) -----
#
# The Megatron-style conjugate operator pair: a model-sharded block wraps its
# projections as
#
#     y = tp_psum(h @ W_out_shard, axis)   where   h = act(tp_replicate(x,
#     axis) @ W_in_shard)
#
# `tp_replicate` (the "f" operator) is identity in the forward pass and psums
# the cotangent in the backward pass — the residual stream enters replicated
# and its gradient must re-synchronize after each rank back-propagated only
# through its own head/feature shard. `tp_psum` ("g") is the conjugate: psum
# forward (the out-projection computes a partial sum over the sharded
# contraction dim), identity backward (the incoming cotangent is already
# replicated). Together they keep every residual-stream activation AND its
# gradient replicated across the model group while weights stay sharded.
#
# Both directions are written out explicitly via custom_vjp: inside the
# fully-manual shard_map regions this repo uses (check_vma=False, JAX
# 0.4.30+), the built-in transpose of a bare lax.psum does NOT produce the
# replicated-input gradient this pattern needs (tests/test_hybrid.py pins
# the correct values against a dense single-rank reference).

@functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def tp_replicate(x: jax.Array, axes) -> jax.Array:
    """f operator: identity forward; backward psums the cotangent over `axes`.

    Place on a replicated activation entering model-sharded projections."""
    del axes
    return x


def _tp_replicate_fwd(x, axes):
    del axes
    return x, None


def _tp_replicate_bwd(axes, _, ct):
    return (lax.psum(ct, _axes_tuple(axes)),)


tp_replicate.defvjp(_tp_replicate_fwd, _tp_replicate_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def tp_psum(x: jax.Array, axes) -> jax.Array:
    """g operator: psum forward (combine per-shard partial sums); identity
    backward (the cotangent arrives replicated across the model group)."""
    return lax.psum(x, _axes_tuple(axes))


def _tp_psum_fwd(x, axes):
    return lax.psum(x, _axes_tuple(axes)), None


def _tp_psum_bwd(axes, _, ct):
    del axes
    return (ct,)


tp_psum.defvjp(_tp_psum_fwd, _tp_psum_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def tp_psum_scatter(x: jax.Array, axes) -> jax.Array:
    """g operator in the bandwidth-optimal psum_scatter + all_gather form.

    Numerically identical to `tp_psum` but decomposed the way a ring
    allreduce is: each rank combines 1/g of the trailing feature dim, then
    the shards are gathered back. Requires the trailing dim to divide by the
    group size."""
    return _psum_scatter_gather(x, _axes_tuple(axes))


def _psum_scatter_gather(x, ax):
    dim = x.ndim - 1
    y = x
    for a in ax:
        y = lax.psum_scatter(y, a, scatter_dimension=dim, tiled=True)
    for a in reversed(ax):
        y = lax.all_gather(y, a, axis=dim, tiled=True)
    return y


def _tp_psum_scatter_fwd(x, axes):
    return _psum_scatter_gather(x, _axes_tuple(axes)), None


def _tp_psum_scatter_bwd(axes, _, ct):
    del axes
    return (ct,)


tp_psum_scatter.defvjp(_tp_psum_scatter_fwd, _tp_psum_scatter_bwd)


@dataclasses.dataclass(frozen=True)
class TPComm:
    """Activation-exchange communicator for one model-parallel mesh axis.

    The CommEngine hands this out (``engine.tp``) when its plan carries a
    tensor-parallel axis, so model code and the gradient-bucket path share
    one comm surface; the f/g ops are also callable directly
    (`tp_replicate` / `tp_psum`)."""

    axis: str

    def replicate(self, x: jax.Array) -> jax.Array:
        return tp_replicate(x, self.axis)

    def psum(self, x: jax.Array, *, scatter: bool = False) -> jax.Array:
        if scatter:
            return tp_psum_scatter(x, self.axis)
        return tp_psum(x, self.axis)

    @property
    def size(self) -> int:
        return axis_size(self.axis)


@dataclasses.dataclass(frozen=True)
class Comm:
    """A communicator bound to a mesh + manual axes (MLSL 'distribution').

    `data_axes` are the gradient-reduction axes (data parallel dimension);
    `model_axis` is the node-group axis used for model/hybrid parallelism.
    When the data-parallel dimension is factored over the machine hierarchy,
    `node_axis`/`local_axis` name the inter-node (fabric) and intra-node
    (fast link) axes and `allreduce` routes through the two-level path
    (repro.core.hier) with per-level wire precision.
    """

    mesh: jax.sharding.Mesh
    data_axes: tuple
    model_axis: str | None = "model"
    node_axis: str | None = None       # inter-node fabric axis
    local_axis: str | None = None      # intra-node fast-link axis

    def run(self, fn: Callable, in_specs, out_specs, *args,
            extra_manual_axes: Sequence[str] = ()):
        """Run `fn` manually over the data axes (model axis stays GSPMD)."""
        manual = set(self.data_axes) | set(extra_manual_axes)
        wrapped = compat.shard_map(fn, mesh=self.mesh, in_specs=in_specs,
                                   out_specs=out_specs, axis_names=manual,
                                   check_vma=False)
        return wrapped(*args)

    @property
    def data_parallel_size(self) -> int:
        size = 1
        for a in self.data_axes:
            size *= self.mesh.shape[a]
        return size

    @property
    def model_parallel_size(self) -> int:
        if self.model_axis is None:
            return 1
        return self.mesh.shape[self.model_axis]

    # -- machine-hierarchy awareness ---------------------------------------

    @property
    def hierarchical(self) -> bool:
        """True when the data axes are factored over the node hierarchy."""
        return (self.node_axis is not None and self.local_axis is not None
                and self.node_axis in self.data_axes
                and self.local_axis in self.data_axes)

    @property
    def node_size(self) -> int:
        return self.mesh.shape[self.node_axis] if self.node_axis else 1

    @property
    def local_size(self) -> int:
        return self.mesh.shape[self.local_axis] if self.local_axis else 1

    def hier_spec(self, *, wire_intra: str = WIRE_FP32,
                  wire_inter: str = WIRE_FP32, error_feedback: bool = False):
        from repro.core import hier as hier_lib
        assert self.hierarchical, (self.node_axis, self.local_axis,
                                   self.data_axes)
        return hier_lib.HierSpec(node_axis=self.node_axis,
                                 local_axis=self.local_axis,
                                 wire_intra=wire_intra,
                                 wire_inter=wire_inter,
                                 error_feedback=error_feedback)

    def allreduce(self, x: jax.Array, *, wire: str = WIRE_FP32,
                  wire_intra: str | None = None,
                  mean: bool = False) -> jax.Array:
        """Gradient allreduce over the data axes (callable inside `run`).

        On a hierarchical communicator this is the two-level path: `wire`
        selects the fabric leg, `wire_intra` the intra-node legs (defaults
        to bf16 when the fabric is lossy, fp32 otherwise).
        """
        if not self.hierarchical:
            return allreduce(x, self.data_axes, wire=wire, mean=mean)
        from repro.core import hier as hier_lib
        if wire_intra is None:
            wire_intra = hier_lib.default_wire_intra(wire)
        spec = self.hier_spec(wire_intra=wire_intra, wire_inter=wire)
        return hier_lib.hier_allreduce(x, spec, mean=mean)
