"""Gradient bucketing + message prioritization (paper C4/C5).

MLSL's runtime preempts in-flight bulk gradient exchanges so the *first*
layer's small, latency-bound allreduce — whose result is needed immediately
at the start of the next forward pass — completes first. XLA programs are
statically scheduled, so the same policy is expressed *structurally*:

  1. gradients are fused into buckets (flattened + concatenated, MLSL/Horovod
     message fusion), keyed by the layer order of the FORWARD pass;
  2. buckets are reduced in priority order (forward-first), each bucket's
     collective made dependent on the previous one's completion via
     `lax.optimization_barrier` token threading.

In `comm=mlsl` mode the collectives are explicit (repro.core.collectives), so
the chain provably orders them in the HLO (tests assert this). In
`comm=gspmd` mode the reductions are partitioner-inserted and the chain is a
best-effort scheduling hint placed between gradient computation and the
optimizer; the quantitative benefit is established by the simulator either
way (benchmarks/bench_prioritization.py).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
from jax import lax


@dataclasses.dataclass(frozen=True)
class Bucket:
    """A fused gradient message."""

    priority: int              # 0 == most urgent (first forward layers)
    leaf_ids: tuple            # indices into the flattened gradient tree
    sizes: tuple               # element counts, same order as leaf_ids
    shapes: tuple
    dtypes: tuple

    @property
    def n_elems(self) -> int:
        return int(sum(self.sizes))


@dataclasses.dataclass(frozen=True)
class BucketPlan:
    buckets: tuple             # ordered by priority (most urgent first)
    treedef: object            # treedef of the gradient tree


def plan_buckets(grad_tree, layer_index: Callable[[tuple], float] | None = None,
                 *, bucket_bytes: float = 25e6, bytes_per_elem: float = 4.0,
                 group_key: Callable[[tuple], object] | None = None) -> BucketPlan:
    """Group gradient leaves into fused messages ordered by forward depth.

    `layer_index(path)` maps a tree path to the layer's position in the
    forward pass (0 == first). Defaults to the tree's natural leaf order.
    A new bucket starts whenever the running size exceeds `bucket_bytes`,
    so early (urgent) layers end up in small, low-latency messages and bulk
    weight gradients in large, bandwidth-efficient ones.
    """
    leaves_with_paths = jax.tree_util.tree_leaves_with_path(grad_tree)
    treedef = jax.tree_util.tree_structure(grad_tree)
    order = list(range(len(leaves_with_paths)))
    if layer_index is not None:
        order.sort(key=lambda i: layer_index(leaves_with_paths[i][0]))

    buckets = []
    cur_ids, cur_sizes, cur_shapes, cur_dtypes, cur_bytes = [], [], [], [], 0.0
    cur_key = object()
    for i in order:
        path, leaf = leaves_with_paths[i]
        key = group_key(path) if group_key else None
        if group_key and cur_ids and key != cur_key:
            # sharding boundary: never fuse differently-sharded leaves
            buckets.append(Bucket(priority=len(buckets), leaf_ids=tuple(cur_ids),
                                  sizes=tuple(cur_sizes), shapes=tuple(cur_shapes),
                                  dtypes=tuple(cur_dtypes)))
            cur_ids, cur_sizes, cur_shapes, cur_dtypes, cur_bytes = [], [], [], [], 0.0
        cur_key = key
        cur_ids.append(i)
        cur_sizes.append(int(leaf.size))
        cur_shapes.append(tuple(leaf.shape))
        cur_dtypes.append(leaf.dtype)
        cur_bytes += leaf.size * bytes_per_elem
        if cur_bytes >= bucket_bytes:
            buckets.append(Bucket(priority=len(buckets), leaf_ids=tuple(cur_ids),
                                  sizes=tuple(cur_sizes), shapes=tuple(cur_shapes),
                                  dtypes=tuple(cur_dtypes)))
            cur_ids, cur_sizes, cur_shapes, cur_dtypes, cur_bytes = [], [], [], [], 0.0
    if cur_ids:
        buckets.append(Bucket(priority=len(buckets), leaf_ids=tuple(cur_ids),
                              sizes=tuple(cur_sizes), shapes=tuple(cur_shapes),
                              dtypes=tuple(cur_dtypes)))
    return BucketPlan(buckets=tuple(buckets), treedef=treedef)


def fuse_bucket(leaves: Sequence[jax.Array], bucket: Bucket) -> jax.Array:
    """Concatenate a bucket's gradient leaves into one flat f32 message."""
    parts = [leaves[i].reshape(-1).astype(jnp.float32) for i in bucket.leaf_ids]
    return jnp.concatenate(parts) if len(parts) > 1 else parts[0]


def unfuse_bucket(flat: jax.Array, bucket: Bucket) -> dict:
    """Split a fused message back into {leaf_id: leaf}."""
    out = {}
    off = 0
    for lid, size, shape, dtype in zip(bucket.leaf_ids, bucket.sizes,
                                       bucket.shapes, bucket.dtypes):
        out[lid] = flat[off:off + size].reshape(shape).astype(dtype)
        off += size
    return out


def _token_of(x: jax.Array) -> jax.Array:
    """A scalar data-dependent on x, cheap to thread through barriers."""
    return x.reshape(-1)[0]


def reduce_with_priority(grad_tree, reduce_fn: Callable[[jax.Array, Bucket], jax.Array],
                         plan: BucketPlan, *, prioritize: bool = True,
                         fuse: bool = True):
    """Apply `reduce_fn(message, bucket)` per bucket, priority-chained.

    With `prioritize=True`, bucket k+1's message is data-dependent on bucket
    k's reduced result (via optimization_barrier token threading), forcing the
    compiler to issue/retire collectives in forward-layer order — the
    structural equivalent of MLSL preempting bulk transfers. With False, the
    buckets are left unordered (FIFO/bulk-synchronous behaviour, the
    baseline the paper compares against).

    `fuse=False` keeps each leaf as its own message and only threads the
    barrier chain. THIS IS REQUIRED UNDER GSPMD-SHARDED GRADIENTS: flattening
    and concatenating a sharded tensor forces the partitioner to all-gather
    it (measured 2x625 GB/chip on arctic-480b -- EXPERIMENTS.md §Perf
    iteration A0). Message fusion is only meaningful where the caller
    controls the wire layout (the mlsl manual data path) and the leaves are
    replicated over the auto axes.
    """
    leaves = jax.tree_util.tree_leaves(grad_tree)
    new_leaves = list(leaves)
    token = None
    for bucket in plan.buckets:
        if fuse:
            flat = fuse_bucket(leaves, bucket)
            if prioritize and token is not None:
                flat, token = lax.optimization_barrier((flat, token))
            reduced = reduce_fn(flat, bucket)
            if prioritize:
                token = _token_of(reduced)
            for lid, leaf in unfuse_bucket(reduced, bucket).items():
                new_leaves[lid] = leaf
        else:
            vals = [leaves[i] for i in bucket.leaf_ids]
            if prioritize and token is not None:
                vals, token = lax.optimization_barrier((vals, token))
            vals = [reduce_fn(v, bucket) for v in vals]
            if prioritize:
                token = _token_of(vals[0])
            for lid, leaf in zip(bucket.leaf_ids, vals):
                new_leaves[lid] = leaf
    return jax.tree_util.tree_unflatten(plan.treedef, new_leaves)


def route_buckets(plan: BucketPlan, topo, nodes: int, *,
                  bytes_per_elem: float = 4.0, fault=None,
                  wire: str = "fp32", ef: bool = False,
                  fused_quant: bool = True) -> tuple:
    """Per-bucket flat-vs-hierarchical routing over a machine hierarchy.

    For each fused message, asks the per-level cost model which allreduce
    decomposition is cheaper on `topo` (repro.core.hw.Topology) with `nodes`
    inter-node ranks. Returns one of planner.ALGO_FLAT / ALGO_HIER per
    bucket, in plan order -- the structural analog of MLSL choosing its
    intra/inter phase split per message. Small, latency-bound urgent buckets
    can legitimately route flat while bulk buckets go hierarchical.

    `fault` (simulator.FaultSpec) re-routes every bucket under an injected
    degradation of the topology's links: a degraded inter fabric moves the
    flat/hier crossover, so buckets that routed flat on the healthy machine
    may re-route onto the two-level decomposition (and vice versa for a
    degraded intra transport).

    `wire`/`ef`/`fused_quant` (the engine's wire format and kernel-fusion
    setting) charge the int8 quantization-overhead term on both candidate
    routes, so the crossover reflects the transform cost too.
    """
    from repro.core import planner as pl
    return tuple(
        pl.choose_allreduce_algo(b.n_elems * bytes_per_elem, nodes, topo,
                                 fault=fault, wire=wire, ef=ef,
                                 fused_quant=fused_quant)
        for b in plan.buckets)


def chain_barrier(values, token):
    """Expose the token-threading primitive for other schedulers (serving,
    activation prioritization in model/hybrid parallelism)."""
    if token is None:
        return values, None
    values, token = lax.optimization_barrier((values, token))
    return values, token


def default_layer_index(path: tuple) -> float:
    """Heuristic forward-depth key for common param-tree layouts.

    Understands paths like ('layers', 3, 'attn', 'wq') and stacked-scan params
    ('blocks', 'attn', 'wq') (depth unknown -> middle), with 'embed' first and
    'head'/'final' last.
    """
    names = []
    idx = None
    for p in path:
        if hasattr(p, "key"):
            names.append(str(p.key))
        elif hasattr(p, "idx"):
            idx = p.idx
        else:
            names.append(str(p))
    joined = "/".join(names).lower()
    if "embed" in joined or "tok_emb" in joined:
        return -1.0
    if "head" in joined or "final" in joined or "lm_out" in joined:
        return 1e9
    if idx is not None:
        return float(idx)
    return 1e6  # stacked/unknown: after explicit layers, before the head
