"""Hardware models shared by the C2C analysis, the network simulator, and the
roofline harness.

Two families are modeled:
  * the paper's platforms (Intel Xeon Gold 6148 "Skylake" nodes on 10 GbE
    Ethernet and on Intel Omni-Path) -- used to validate the paper's own
    claims (prioritization 1.8-2.2x, ResNet-50 scaling, Fig. 2);
  * the reproduction target (TPU v5e pods over ICI) -- used for the roofline
    analysis of the dry-runs.

All bandwidths are bytes/second, latencies are seconds, flops are FLOP/s.
"""

from __future__ import annotations

import dataclasses
import math


@dataclasses.dataclass(frozen=True)
class Chip:
    """A compute element (one node in the paper's terms, one chip in ours)."""

    name: str
    peak_flops: float          # peak FLOP/s at the training precision
    mem_bw: float              # bytes/s main-memory bandwidth
    mem_bytes: float           # capacity, bytes
    # Fraction of peak a well-tuned dense workload sustains; used only by the
    # simulator to turn FLOPs into seconds (the roofline harness reports raw
    # peak-referred terms and never applies this).
    sustained_frac: float = 0.55


@dataclasses.dataclass(frozen=True)
class Link:
    """A network link (NIC in the paper, ICI link on TPU)."""

    name: str
    bw: float                  # bytes/s per direction
    latency: float             # per-message latency, seconds


# --- reproduction target: TPU v5e ------------------------------------------
TPU_V5E = Chip("tpu-v5e", peak_flops=197e12, mem_bw=819e9, mem_bytes=16e9)
ICI_LINK = Link("ici", bw=50e9, latency=1e-6)
# inter-pod data-center network: the slow fabric of the TPU hierarchy
DCN_LINK = Link("dcn", bw=6.25e9, latency=50e-6)

# --- paper platforms ---------------------------------------------------------
# 2-socket Xeon Gold 6148: 2 x 20 cores x 2.4 GHz x 32 SP FLOP/cycle ~ 6.1 TF
# fp32 peak; DL kernels of the era sustained roughly half of that with MKL-DNN.
XEON_6148 = Chip("xeon-6148-2s", peak_flops=6.1e12, mem_bw=2 * 128e9,
                 mem_bytes=192e9, sustained_frac=0.45)
ETH_10G = Link("10gbe", bw=1.25e9, latency=30e-6)
OMNIPATH = Link("omni-path-100", bw=12.5e9, latency=1.5e-6)
# intra-node transport (shared memory / QPI): what MLSL's intra-node phase
# of the two-level allreduce rides on (You et al. 1708.02983 §4)
SHM_LINK = Link("shm-qpi", bw=40e9, latency=0.3e-6)


# --- machine hierarchy -------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class LinkDegradation:
    """Multiplicative degradation of one link (congestion, oversubscription,
    a flaky cable): effective bw = bw * bw_factor (0 < factor <= 1),
    effective latency = latency * latency_factor (factor >= 1)."""

    bw_factor: float = 1.0
    latency_factor: float = 1.0

    @property
    def healthy(self) -> bool:
        return self.bw_factor >= 1.0 and self.latency_factor <= 1.0

    def apply(self, link: Link) -> Link:
        if self.healthy:
            return link
        return Link(name=f"{link.name}!deg",
                    bw=link.bw * min(self.bw_factor, 1.0),
                    latency=link.latency * max(self.latency_factor, 1.0))


HEALTHY = LinkDegradation()


@dataclasses.dataclass(frozen=True)
class Topology:
    """Two-level machine hierarchy: `local_size` ranks per node on a fast
    `intra` link; nodes connected by the slower `inter` fabric.

    `intra_fault` / `inter_fault` are per-link degradation factors and
    `straggler` the slowest node's compute slowdown (>= 1) — the scenario
    knobs Keuper & Pfreundt (arXiv:1609.06870) identify as where scale-out
    limits actually appear. The collective time models below always cost on
    the *effective* (degraded) links; a healthy topology is the default."""

    name: str
    intra: Link
    inter: Link
    local_size: int
    intra_fault: LinkDegradation = HEALTHY
    inter_fault: LinkDegradation = HEALTHY
    straggler: float = 1.0
    # per-rank main-memory bandwidth (bytes/s): what the wire-quantization
    # transform passes are paced by. Defaults to the Xeon 6148 node the
    # paper's platforms are built from; TPU topologies override with HBM.
    mem_bw: float = 2 * 128e9

    def flat_size(self, nodes: int) -> int:
        return nodes * self.local_size

    @property
    def effective_intra(self) -> Link:
        return self.intra_fault.apply(self.intra)

    @property
    def effective_inter(self) -> Link:
        return self.inter_fault.apply(self.inter)

    def degrade(self, *, intra_bw: float = 1.0, intra_latency: float = 1.0,
                inter_bw: float = 1.0, inter_latency: float = 1.0,
                straggler: float = 1.0) -> "Topology":
        """A degraded copy; factors COMPOSE with any existing degradation."""
        return dataclasses.replace(
            self,
            intra_fault=LinkDegradation(
                self.intra_fault.bw_factor * intra_bw,
                self.intra_fault.latency_factor * intra_latency),
            inter_fault=LinkDegradation(
                self.inter_fault.bw_factor * inter_bw,
                self.inter_fault.latency_factor * inter_latency),
            straggler=max(self.straggler, 1.0) * max(straggler, 1.0))


# cloud VMs without a shared-memory transport: intra-host ranks talk MPI over
# the virtio/TCP loopback stack while the fabric NIC is SR-IOV passthrough at
# near line rate -- the virtualization overhead case of Keuper & Pfreundt
# (arXiv:1609.06870). Uniquely, the *intra* link is SLOWER than the fabric,
# so bulk messages legitimately route flat (hier's two intra phases cost more
# than the fabric-volume saving) until the fabric degrades.
VIRTIO_TCP = Link("virtio-tcp", bw=0.9e9, latency=40e-6)
SRIOV_10G = Link("sriov-10gbe", bw=1.25e9, latency=35e-6)

# canonical hierarchies
CLOUD_10G = Topology("xeon-shm-10gbe", intra=SHM_LINK, inter=ETH_10G,
                     local_size=4)
HPC_OPA = Topology("xeon-shm-opa", intra=SHM_LINK, inter=OMNIPATH,
                   local_size=4)
TPU_MULTIPOD = Topology("v5e-ici-dcn", intra=ICI_LINK, inter=DCN_LINK,
                        local_size=256, mem_bw=TPU_V5E.mem_bw)
CLOUD_VIRT = Topology("cloud-virtio-sriov", intra=VIRTIO_TCP,
                      inter=SRIOV_10G, local_size=4)

# by-name lookup for config surfaces (train.CommConfig.topo stays a plain
# string so configs remain hashable/serializable)
TOPOLOGIES = {t.name: t for t in (CLOUD_10G, HPC_OPA, TPU_MULTIPOD,
                                  CLOUD_VIRT)}


# --- collective time models --------------------------------------------------
# Classic alpha-beta models; ring algorithms for bandwidth-bound collectives
# (what MLSL/MPI used on Ethernet/OPA, and a faithful per-link model for ICI).

def ring_allreduce_time(nbytes: float, p: int, link: Link) -> float:
    """Ring allreduce: 2(p-1) steps, each moving nbytes/p."""
    if p <= 1 or nbytes <= 0:
        return 0.0
    steps = 2 * (p - 1)
    return steps * link.latency + steps * (nbytes / p) / link.bw


def reduce_scatter_time(nbytes: float, p: int, link: Link) -> float:
    if p <= 1 or nbytes <= 0:
        return 0.0
    steps = p - 1
    return steps * link.latency + steps * (nbytes / p) / link.bw


def all_gather_time(nbytes: float, p: int, link: Link) -> float:
    # nbytes = full (gathered) size.
    if p <= 1 or nbytes <= 0:
        return 0.0
    steps = p - 1
    return steps * link.latency + steps * (nbytes / p) / link.bw


def all_to_all_time(nbytes: float, p: int, link: Link) -> float:
    """Pairwise-exchange all-to-all; nbytes = local send buffer size."""
    if p <= 1 or nbytes <= 0:
        return 0.0
    steps = p - 1
    return steps * link.latency + nbytes * (p - 1) / p / link.bw


# --- wire-quantization overhead (the int8 transform's HBM traffic) ----------
# Per-element HBM bytes of the int8 wire transform, by pass. The fused Pallas
# kernels (repro.kernels.quant8) read and write each gradient element once
# per leg direction; the composed (unfused) path materializes the cast, the
# error-feedback add, and the residual update as separate round-trips.
#
#   quantize side (per element of the quantized message volume):
#     fused, EF:     read bf16 x (2) + read f32 residual (4)
#                    + write q (1) + write residual (4)          = 11 B
#     unfused, EF:   cast bf16->f32 (2r+4w=6) + EF add (4+4r+4w=12)
#                    + quantize (4r+1w=5) + dequant for the error (1r+4w=5)
#                    + residual subtract (4+4r+4w=12)            = 40 B
#     fused, plain:  read bf16 (2) + write q (1)                 =  3 B
#     unfused, plain: cast (6) + quantize (5)                    = 11 B
#   dequantize side (gather):
#     fused:         read q (1) + read f32 acc (4) + write (4)   =  9 B
#     unfused:       dequant (1r+4w=5) + accumulate (4+4r+4w=12) = 17 B
#
# (per-block scales are n/512 of the volume -- ignored as noise.)

_QUANT_BYTES = {                     # (ef, fused) -> quantize-side B/elem
    (True, True): 11.0, (True, False): 40.0,
    (False, True): 3.0, (False, False): 11.0,
}
_DEQUANT_BYTES = {True: 9.0, False: 17.0}      # fused -> gather-side B/elem


def quant_hbm_bytes(n_elems: float, *, ef: bool = False,
                    fused: bool = True) -> float:
    """Total modeled HBM traffic (bytes) of one int8 wire transform over an
    n_elems message: quantize side + gather-side dequantize/accumulate."""
    if n_elems <= 0:
        return 0.0
    return n_elems * (_QUANT_BYTES[(ef, fused)] + _DEQUANT_BYTES[fused])


def quant_overhead_time(nbytes: float, topo: Topology, *, ef: bool = False,
                        fused: bool = True) -> float:
    """Time the int8 wire transform adds to one leg: passes x bytes / mem_bw.

    `nbytes` is the f32 size of the quantized message volume (the shard the
    leg actually quantizes); the per-pass byte counts above are per element,
    so elems = nbytes / 4."""
    if nbytes <= 0:
        return 0.0
    return quant_hbm_bytes(nbytes / 4.0, ef=ef, fused=fused) / topo.mem_bw


def hier_allreduce_time(nbytes: float, nodes: int, topo: Topology, *,
                        wire_inter: str = "fp32", ef: bool = False,
                        fused_quant: bool = True) -> float:
    """Two-level allreduce over `nodes` nodes of `topo.local_size` ranks.

    intra-node reduce-scatter (full volume, fast link) + inter-node ring
    allreduce on nbytes/local_size (slow fabric) + intra-node all-gather.
    Reduces the fabric volume by local_size vs `flat_allreduce_time`.

    With the int8 fabric wire (`wire_inter="int8"`), the per-leg
    quantization overhead (passes x bytes / mem_bw) is charged on the
    fabric-shard volume -- `fused_quant` selects the single-pass kernels,
    so the planner sees the fusion win.
    """
    local = topo.local_size
    if nbytes <= 0 or topo.flat_size(nodes) <= 1:
        return 0.0
    t = reduce_scatter_time(nbytes, local, topo.effective_intra)
    t += ring_allreduce_time(nbytes / max(local, 1), nodes,
                             topo.effective_inter)
    t += all_gather_time(nbytes, local, topo.effective_intra)
    if wire_inter == "int8":
        t += quant_overhead_time(nbytes / max(local, 1), topo, ef=ef,
                                 fused=fused_quant)
    return t


def flat_allreduce_time(nbytes: float, nodes: int, topo: Topology, *,
                        wire: str = "fp32", ef: bool = False,
                        fused_quant: bool = True) -> float:
    """Single-level ring over all nodes*local ranks, paced end to end by the
    (effective) fabric: the topology-unaware algorithm does not exploit the
    intra-node transport, so every hop rides the fabric path (all of a
    node's ranks serialize on its NIC). The int8 wire's quantization
    overhead is charged on the full message (the gather-side dequantize
    consumes the fully-gathered volume)."""
    t = ring_allreduce_time(nbytes, topo.flat_size(nodes),
                            topo.effective_inter)
    if wire == "int8":
        t += quant_overhead_time(nbytes, topo, ef=ef, fused=fused_quant)
    return t


def latency_bound_fraction(nbytes: float, p: int, link: Link) -> float:
    """Fraction of a ring allreduce spent in per-message latency.

    The paper's first-layer gradients are 'latency bound': this is ~1 for
    small messages and ->0 for large ones.
    """
    t = ring_allreduce_time(nbytes, p, link)
    if t == 0:
        return 0.0
    return (2 * (p - 1) * link.latency) / t


def tree_depth(p: int) -> int:
    return max(1, int(math.ceil(math.log2(max(p, 2)))))
