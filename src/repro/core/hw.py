"""Hardware models shared by the C2C analysis, the network simulator, and the
roofline harness.

Two families are modeled:
  * the paper's platforms (Intel Xeon Gold 6148 "Skylake" nodes on 10 GbE
    Ethernet and on Intel Omni-Path) -- used to validate the paper's own
    claims (prioritization 1.8-2.2x, ResNet-50 scaling, Fig. 2);
  * the reproduction target (TPU v5e pods over ICI) -- used for the roofline
    analysis of the dry-runs.

All bandwidths are bytes/second, latencies are seconds, flops are FLOP/s.
"""

from __future__ import annotations

import dataclasses
import math


@dataclasses.dataclass(frozen=True)
class Chip:
    """A compute element (one node in the paper's terms, one chip in ours)."""

    name: str
    peak_flops: float          # peak FLOP/s at the training precision
    mem_bw: float              # bytes/s main-memory bandwidth
    mem_bytes: float           # capacity, bytes
    # Fraction of peak a well-tuned dense workload sustains; used only by the
    # simulator to turn FLOPs into seconds (the roofline harness reports raw
    # peak-referred terms and never applies this).
    sustained_frac: float = 0.55


@dataclasses.dataclass(frozen=True)
class Link:
    """A network link (NIC in the paper, ICI link on TPU)."""

    name: str
    bw: float                  # bytes/s per direction
    latency: float             # per-message latency, seconds


# --- reproduction target: TPU v5e ------------------------------------------
TPU_V5E = Chip("tpu-v5e", peak_flops=197e12, mem_bw=819e9, mem_bytes=16e9)
ICI_LINK = Link("ici", bw=50e9, latency=1e-6)
# inter-pod data-center network: the slow fabric of the TPU hierarchy
DCN_LINK = Link("dcn", bw=6.25e9, latency=50e-6)

# --- paper platforms ---------------------------------------------------------
# 2-socket Xeon Gold 6148: 2 x 20 cores x 2.4 GHz x 32 SP FLOP/cycle ~ 6.1 TF
# fp32 peak; DL kernels of the era sustained roughly half of that with MKL-DNN.
XEON_6148 = Chip("xeon-6148-2s", peak_flops=6.1e12, mem_bw=2 * 128e9,
                 mem_bytes=192e9, sustained_frac=0.45)
ETH_10G = Link("10gbe", bw=1.25e9, latency=30e-6)
OMNIPATH = Link("omni-path-100", bw=12.5e9, latency=1.5e-6)
# intra-node transport (shared memory / QPI): what MLSL's intra-node phase
# of the two-level allreduce rides on (You et al. 1708.02983 §4)
SHM_LINK = Link("shm-qpi", bw=40e9, latency=0.3e-6)


# --- machine hierarchy -------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Topology:
    """Two-level machine hierarchy: `local_size` ranks per node on a fast
    `intra` link; nodes connected by the slower `inter` fabric."""

    name: str
    intra: Link
    inter: Link
    local_size: int

    def flat_size(self, nodes: int) -> int:
        return nodes * self.local_size


# canonical hierarchies
CLOUD_10G = Topology("xeon-shm-10gbe", intra=SHM_LINK, inter=ETH_10G,
                     local_size=4)
HPC_OPA = Topology("xeon-shm-opa", intra=SHM_LINK, inter=OMNIPATH,
                   local_size=4)
TPU_MULTIPOD = Topology("v5e-ici-dcn", intra=ICI_LINK, inter=DCN_LINK,
                        local_size=256)

# by-name lookup for config surfaces (train.CommConfig.topo stays a plain
# string so configs remain hashable/serializable)
TOPOLOGIES = {t.name: t for t in (CLOUD_10G, HPC_OPA, TPU_MULTIPOD)}


# --- collective time models --------------------------------------------------
# Classic alpha-beta models; ring algorithms for bandwidth-bound collectives
# (what MLSL/MPI used on Ethernet/OPA, and a faithful per-link model for ICI).

def ring_allreduce_time(nbytes: float, p: int, link: Link) -> float:
    """Ring allreduce: 2(p-1) steps, each moving nbytes/p."""
    if p <= 1 or nbytes <= 0:
        return 0.0
    steps = 2 * (p - 1)
    return steps * link.latency + steps * (nbytes / p) / link.bw


def reduce_scatter_time(nbytes: float, p: int, link: Link) -> float:
    if p <= 1 or nbytes <= 0:
        return 0.0
    steps = p - 1
    return steps * link.latency + steps * (nbytes / p) / link.bw


def all_gather_time(nbytes: float, p: int, link: Link) -> float:
    # nbytes = full (gathered) size.
    if p <= 1 or nbytes <= 0:
        return 0.0
    steps = p - 1
    return steps * link.latency + steps * (nbytes / p) / link.bw


def all_to_all_time(nbytes: float, p: int, link: Link) -> float:
    """Pairwise-exchange all-to-all; nbytes = local send buffer size."""
    if p <= 1 or nbytes <= 0:
        return 0.0
    steps = p - 1
    return steps * link.latency + nbytes * (p - 1) / p / link.bw


def hier_allreduce_time(nbytes: float, nodes: int, topo: Topology) -> float:
    """Two-level allreduce over `nodes` nodes of `topo.local_size` ranks.

    intra-node reduce-scatter (full volume, fast link) + inter-node ring
    allreduce on nbytes/local_size (slow fabric) + intra-node all-gather.
    Reduces the fabric volume by local_size vs `flat_allreduce_time`.
    """
    local = topo.local_size
    if nbytes <= 0 or topo.flat_size(nodes) <= 1:
        return 0.0
    t = reduce_scatter_time(nbytes, local, topo.intra)
    t += ring_allreduce_time(nbytes / max(local, 1), nodes, topo.inter)
    t += all_gather_time(nbytes, local, topo.intra)
    return t


def flat_allreduce_time(nbytes: float, nodes: int, topo: Topology) -> float:
    """Single-level ring over all nodes*local ranks: every hop is paced by
    the slowest link in the ring, i.e. the fabric."""
    return ring_allreduce_time(nbytes, topo.flat_size(nodes), topo.inter)


def latency_bound_fraction(nbytes: float, p: int, link: Link) -> float:
    """Fraction of a ring allreduce spent in per-message latency.

    The paper's first-layer gradients are 'latency bound': this is ~1 for
    small messages and ->0 for large ones.
    """
    t = ring_allreduce_time(nbytes, p, link)
    if t == 0:
        return 0.0
    return (2 * (p - 1) * link.latency) / t


def tree_depth(p: int) -> int:
    return max(1, int(math.ceil(math.log2(max(p, 2)))))
