"""Snowflake Arctic (480B): dense-MoE hybrid -- 128-expert top-2 MoE with a
parallel dense residual MLP per layer [hf:Snowflake/snowflake-arctic-base]."""

from repro.configs.base import AttnConfig, MoEConfig, ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b", arch_type="moe", n_layers=35, d_model=7168,
    vocab=32000, block_pattern=("moe",), d_ff=4864, mlp_act="silu",
    attn=AttnConfig(n_heads=56, n_kv=8, head_dim=128),
    moe=MoEConfig(n_experts=128, top_k=2, d_ff=4864, capacity_factor=1.25,
                  dense_residual_ff=4864),
    source="hf:Snowflake/snowflake-arctic-base",
)
