"""Config system: architecture and run configuration dataclasses.

Every assigned architecture is a `ModelConfig` in repro/configs/<id>.py; the
registry (repro.configs.registry) resolves `--arch <id>` strings.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AttnConfig:
    n_heads: int
    n_kv: int
    head_dim: int
    rope_theta: float = 1e4
    rotary_frac: float = 1.0       # fraction of head_dim rotated (ChatGLM: 0.5)
    window: Optional[int] = None   # native sliding window (Mistral: 4096)
    qkv_bias: bool = False
    causal: bool = True


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """Multi-head Latent Attention (DeepSeek-V2 / MiniCPM3)."""

    n_heads: int
    q_lora_rank: int
    kv_lora_rank: int
    qk_nope_dim: int
    qk_rope_dim: int
    v_head_dim: int
    rope_theta: float = 1e4


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff: int
    capacity_factor: float = 1.25
    dense_residual_ff: int = 0     # Arctic: parallel dense MLP of this width
    router_aux_weight: float = 0.01


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba-2 SSD mixer."""

    d_state: int = 128
    head_dim: int = 64
    expand: int = 2
    conv_width: int = 4
    chunk: int = 256
    n_groups: int = 1


@dataclasses.dataclass(frozen=True)
class RGLRUConfig:
    """RecurrentGemma / Griffin recurrent block."""

    lru_width: int
    conv_width: int = 4
    c_constant: float = 8.0


@dataclasses.dataclass(frozen=True)
class EncoderConfig:
    """Whisper-style encoder consuming precomputed frame embeddings (the conv
    + mel frontend is a stub per the assignment)."""

    n_layers: int
    n_frames: int = 1500
    d_input: int = 768             # frontend output dim (== d_model for whisper)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str                 # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    vocab: int
    block_pattern: Tuple[str, ...]  # cycled over layers: attn|mla|moe|ssm|rglru|local
    d_ff: int = 0
    mlp_act: str = "silu"
    mlp_gated: bool = True
    attn: Optional[AttnConfig] = None
    mla: Optional[MLAConfig] = None
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    rglru: Optional[RGLRUConfig] = None
    encoder: Optional[EncoderConfig] = None
    vlm_img_tokens: int = 0        # >0: prepend this many projected patch embeds
    vlm_d_vision: int = 1024
    norm: str = "rmsnorm"          # rmsnorm | layernorm
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    learned_positions: int = 0     # >0 (whisper): learned abs positions
    embed_scale: bool = False      # multiply embeddings by sqrt(d) (gemma-style)
    logit_softcap: float = 0.0
    dtype: object = jnp.bfloat16
    remat: bool = True
    # long-context variant: dense/full-attention archs get a sliding-window
    # attention cache of this size for the long_500k decode shape only.
    long_context_window: int = 4096
    source: str = ""               # citation

    # -- derived -------------------------------------------------------------

    @property
    def pattern_repeats(self) -> int:
        return self.n_layers // len(self.block_pattern)

    @property
    def tail_layers(self) -> Tuple[str, ...]:
        r = self.n_layers % len(self.block_pattern)
        return self.block_pattern[:r]

    def layer_kind(self, i: int) -> str:
        return self.block_pattern[i % len(self.block_pattern)]

    @property
    def supports_long_decode(self) -> bool:
        """True if a 524k-token decode has bounded per-step state."""
        if self.encoder is not None:
            return False           # enc-dec full attention (whisper): skipped
        return True                # SSM/hybrid native; dense via SWA variant

    @property
    def is_native_long(self) -> bool:
        kinds = set(self.block_pattern)
        if kinds <= {"ssm", "rglru", "local"}:
            return True
        return (self.attn is not None and self.attn.window is not None
                and "attn" not in self.block_pattern)


def reduce_for_smoke(cfg: ModelConfig, *, d_model: int = 256,
                     n_layers: int | None = None, vocab: int = 512,
                     d_ff: int = 512, n_experts: int = 4) -> ModelConfig:
    """Reduced same-family variant for CPU smoke tests (<=2 layers, d<=512)."""
    n_layers = n_layers if n_layers is not None else min(
        2 * len(cfg.block_pattern), max(2, len(cfg.block_pattern)))
    kw = {}
    if cfg.attn is not None:
        hd = 32
        n_heads = max(2, min(4, cfg.attn.n_heads))
        n_kv = max(1, min(cfg.attn.n_kv, n_heads))
        window = None if cfg.attn.window is None else 64
        kw["attn"] = dataclasses.replace(cfg.attn, n_heads=n_heads, n_kv=n_kv,
                                         head_dim=hd, window=window)
    if cfg.mla is not None:
        kw["mla"] = dataclasses.replace(cfg.mla, n_heads=4, q_lora_rank=64,
                                        kv_lora_rank=32, qk_nope_dim=16,
                                        qk_rope_dim=8, v_head_dim=16)
    if cfg.moe is not None:
        kw["moe"] = dataclasses.replace(
            cfg.moe, n_experts=n_experts, top_k=min(cfg.moe.top_k, 2),
            d_ff=d_ff // 2,
            dense_residual_ff=(d_ff // 2 if cfg.moe.dense_residual_ff else 0))
    if cfg.ssm is not None:
        kw["ssm"] = dataclasses.replace(cfg.ssm, d_state=16, head_dim=16,
                                        chunk=16)
    if cfg.rglru is not None:
        kw["rglru"] = dataclasses.replace(cfg.rglru, lru_width=d_model)
    if cfg.encoder is not None:
        kw["encoder"] = dataclasses.replace(cfg.encoder, n_layers=2,
                                            n_frames=16, d_input=d_model)
    if cfg.vlm_img_tokens:
        kw["vlm_img_tokens"] = 8
        kw["vlm_d_vision"] = 64
    if cfg.learned_positions:
        kw["learned_positions"] = 4096
    return dataclasses.replace(
        cfg, name=cfg.name + "-smoke", d_model=d_model, n_layers=n_layers,
        vocab=vocab, d_ff=d_ff, dtype=jnp.float32, remat=False,
        long_context_window=64, **kw)


def param_count_estimate(cfg: ModelConfig) -> float:
    """Rough N for FSDP decisions and 6ND math (exact count comes from defs)."""
    d = cfg.d_model
    n = 2.0 * cfg.vocab * d
    for i in range(cfg.n_layers):
        k = cfg.layer_kind(i)
        if k in ("attn", "local"):
            a = cfg.attn
            n += d * (a.n_heads + 2 * a.n_kv + a.n_heads) * a.head_dim
            n += (3 if cfg.mlp_gated else 2) * d * cfg.d_ff
        elif k == "mla":
            m = cfg.mla
            n += d * m.q_lora_rank + m.q_lora_rank * m.n_heads * (m.qk_nope_dim + m.qk_rope_dim)
            n += d * (m.kv_lora_rank + m.qk_rope_dim)
            n += m.kv_lora_rank * m.n_heads * (m.qk_nope_dim + m.v_head_dim)
            n += m.n_heads * m.v_head_dim * d
            n += (3 if cfg.mlp_gated else 2) * d * cfg.d_ff
        elif k == "moe":
            a = cfg.attn
            n += d * (a.n_heads + 2 * a.n_kv + a.n_heads) * a.head_dim
            n += cfg.moe.n_experts * 3 * d * cfg.moe.d_ff + d * cfg.moe.n_experts
            n += 3 * d * cfg.moe.dense_residual_ff
        elif k == "ssm":
            s = cfg.ssm
            d_in = s.expand * d
            n += d * (2 * d_in + 2 * s.n_groups * s.d_state + d_in // s.head_dim)
            n += d_in * d
        elif k == "rglru":
            r = cfg.rglru
            n += 2 * d * r.lru_width + r.lru_width * d + 3 * r.lru_width
    if cfg.encoder is not None:
        a = cfg.attn
        per = d * 4 * a.n_heads * a.head_dim + 2 * d * cfg.d_ff
        n += cfg.encoder.n_layers * per
        # decoder cross-attention
        n += cfg.n_layers * d * 4 * a.n_heads * a.head_dim
    return float(n)


def active_param_count_estimate(cfg: ModelConfig) -> float:
    """Active params per token (MoE: top_k of n_experts)."""
    if cfg.moe is None:
        return param_count_estimate(cfg)
    full = param_count_estimate(cfg)
    moe_layers = sum(1 for i in range(cfg.n_layers) if cfg.layer_kind(i) == "moe")
    all_experts = moe_layers * cfg.moe.n_experts * 3 * cfg.d_model * cfg.moe.d_ff
    active = moe_layers * cfg.moe.top_k * 3 * cfg.d_model * cfg.moe.d_ff
    return float(full - all_experts + active)
