"""Layer tables for the paper's own workloads: ResNet-50, VGG-16, GoogleNet.

The paper's headline numbers (1.8-2.2x exposed-comm reduction from message
prioritization; ResNet-50 90% scaling at 256 nodes, Fig. 2) are measured on
these CNNs; the benchmark harness feeds these tables into the C2C model and
the discrete-event simulator. Channel/shape specs follow the original
architectures (He et al. 2015; Simonyan & Zisserman 2014; Szegedy et al.
2014) at 224x224 ImageNet resolution.
"""

from __future__ import annotations

from repro.core import c2c


def resnet50_layers():
    L = [c2c.conv_layer("conv1", 3, 64, 7, 112, 112)]
    # (blocks, in_ch, mid_ch, out_ch, spatial)
    stages = [(3, 64, 64, 256, 56), (4, 256, 128, 512, 28),
              (6, 512, 256, 1024, 14), (3, 1024, 512, 2048, 7)]
    for si, (blocks, cin, mid, cout, hw_) in enumerate(stages):
        for b in range(blocks):
            i = cin if b == 0 else cout
            pre = f"res{si+2}{chr(ord('a')+b)}"
            L.append(c2c.conv_layer(f"{pre}_1x1a", i, mid, 1, hw_, hw_))
            L.append(c2c.conv_layer(f"{pre}_3x3", mid, mid, 3, hw_, hw_))
            L.append(c2c.conv_layer(f"{pre}_1x1b", mid, cout, 1, hw_, hw_))
            if b == 0:
                L.append(c2c.conv_layer(f"{pre}_proj", i, cout, 1, hw_, hw_))
    L.append(c2c.fc_layer("fc1000", 2048, 1000))
    return L


def vgg16_layers():
    spec = [(3, 64, 224), (64, 64, 224), (64, 128, 112), (128, 128, 112),
            (128, 256, 56), (256, 256, 56), (256, 256, 56),
            (256, 512, 28), (512, 512, 28), (512, 512, 28),
            (512, 512, 14), (512, 512, 14), (512, 512, 14)]
    L = [c2c.conv_layer(f"conv{i+1}", cin, cout, 3, hw_, hw_)
         for i, (cin, cout, hw_) in enumerate(spec)]
    L.append(c2c.fc_layer("fc6", 512 * 7 * 7, 4096))
    L.append(c2c.fc_layer("fc7", 4096, 4096))
    L.append(c2c.fc_layer("fc8", 4096, 1000))
    return L


# GoogleNet (Inception v1) module channel table:
# (name, spatial, in, 1x1, 3x3red, 3x3, 5x5red, 5x5, poolproj)
_INCEPTION = [
    ("3a", 28, 192, 64, 96, 128, 16, 32, 32),
    ("3b", 28, 256, 128, 128, 192, 32, 96, 64),
    ("4a", 14, 480, 192, 96, 208, 16, 48, 64),
    ("4b", 14, 512, 160, 112, 224, 24, 64, 64),
    ("4c", 14, 512, 128, 128, 256, 24, 64, 64),
    ("4d", 14, 512, 112, 144, 288, 32, 64, 64),
    ("4e", 14, 528, 256, 160, 320, 32, 128, 128),
    ("5a", 7, 832, 256, 160, 320, 32, 128, 128),
    ("5b", 7, 832, 384, 192, 384, 48, 128, 128),
]


def googlenet_layers():
    L = [c2c.conv_layer("conv1", 3, 64, 7, 112, 112),
         c2c.conv_layer("conv2red", 64, 64, 1, 56, 56),
         c2c.conv_layer("conv2", 64, 192, 3, 56, 56)]
    for (name, hw_, cin, c1, c3r, c3, c5r, c5, cp) in _INCEPTION:
        L.append(c2c.conv_layer(f"inc{name}_1x1", cin, c1, 1, hw_, hw_))
        L.append(c2c.conv_layer(f"inc{name}_3x3r", cin, c3r, 1, hw_, hw_))
        L.append(c2c.conv_layer(f"inc{name}_3x3", c3r, c3, 3, hw_, hw_))
        L.append(c2c.conv_layer(f"inc{name}_5x5r", cin, c5r, 1, hw_, hw_))
        L.append(c2c.conv_layer(f"inc{name}_5x5", c5r, c5, 5, hw_, hw_))
        L.append(c2c.conv_layer(f"inc{name}_pool", cin, cp, 1, hw_, hw_))
    L.append(c2c.fc_layer("fc1000", 1024, 1000))
    return L


TOPOLOGIES = {"resnet50": resnet50_layers, "vgg16": vgg16_layers,
              "googlenet": googlenet_layers}
