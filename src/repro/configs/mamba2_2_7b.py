"""Mamba2-2.7B: attention-free SSD (state-space duality) [arXiv:2405.21060]."""

from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b", arch_type="ssm", n_layers=64, d_model=2560,
    vocab=50280, block_pattern=("ssm",), d_ff=0,
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2, conv_width=4, chunk=256),
    tie_embeddings=True, source="arXiv:2405.21060",
)
