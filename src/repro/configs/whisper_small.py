"""Whisper-small: encoder-decoder with conv/mel frontend STUB
[arXiv:2212.04356].

The frontend (log-mel spectrogram + 2x conv) is stubbed per the assignment:
`input_specs` supplies precomputed frame embeddings (B, 1500, 768). The
decoder uses learned positions; the table is sized to the largest assigned
decode shape (32768) rather than Whisper's native 448 -- recorded as a
deviation in DESIGN.md. long_500k is SKIPPED for this arch (full-attention
enc-dec; see DESIGN.md §5).
"""

from repro.configs.base import AttnConfig, EncoderConfig, ModelConfig

CONFIG = ModelConfig(
    name="whisper-small", arch_type="audio", n_layers=12, d_model=768,
    vocab=51865, block_pattern=("cross",), d_ff=3072, mlp_act="gelu",
    mlp_gated=False, norm="layernorm", norm_eps=1e-5,
    attn=AttnConfig(n_heads=12, n_kv=12, head_dim=64),
    encoder=EncoderConfig(n_layers=12, n_frames=1500, d_input=768),
    learned_positions=32768, tie_embeddings=True,
    source="arXiv:2212.04356",
)
