"""MiniCPM3-4B: multi-head latent attention (MLA) dense decoder
[hf:openbmb/MiniCPM3-4B]."""

from repro.configs.base import MLAConfig, ModelConfig

CONFIG = ModelConfig(
    name="minicpm3-4b", arch_type="dense", n_layers=62, d_model=2560,
    vocab=73448, block_pattern=("mla",), d_ff=6400, mlp_act="silu",
    mla=MLAConfig(n_heads=40, q_lora_rank=768, kv_lora_rank=256,
                  qk_nope_dim=64, qk_rope_dim=32, v_head_dim=64),
    source="hf:openbmb/MiniCPM3-4B",
)
