"""ChatGLM3-6B: GQA (kv=2) with 2D/partial RoPE (half the head dims rotated)
[arXiv:2406.12793]."""

from repro.configs.base import AttnConfig, ModelConfig

CONFIG = ModelConfig(
    name="chatglm3-6b", arch_type="dense", n_layers=28, d_model=4096,
    vocab=65024, block_pattern=("attn",), d_ff=13696, mlp_act="silu",
    attn=AttnConfig(n_heads=32, n_kv=2, head_dim=128, rotary_frac=0.5),
    source="arXiv:2406.12793",
)
