"""Yi-6B: llama-arch GQA dense decoder [arXiv:2403.04652]."""

from repro.configs.base import AttnConfig, ModelConfig

CONFIG = ModelConfig(
    name="yi-6b", arch_type="dense", n_layers=32, d_model=4096, vocab=64000,
    block_pattern=("attn",), d_ff=11008, mlp_act="silu", mlp_gated=True,
    attn=AttnConfig(n_heads=32, n_kv=4, head_dim=128, rope_theta=5e6),
    source="arXiv:2403.04652",
)
