"""LLaVA-NeXT (Mistral-7B backbone): VLM with anyres tiling
[hf:llava-hf/llava-v1.6-mistral-7b-hf].

The vision tower (CLIP ViT-L/14-336) + anyres tile packing is a STUB per the
assignment: `input_specs` supplies precomputed patch embeddings (d=1024)
which the (real) projector maps into the LM. 576 base-tile tokens are used;
anyres adds more tiles but does not change the backbone's compute shape per
token. The backbone is Mistral-7B with native 4096-token sliding-window
attention -- which also makes the long_500k decode shape native.
"""

from repro.configs.base import AttnConfig, ModelConfig

N_IMG_TOKENS = 576

CONFIG = ModelConfig(
    name="llava-next-mistral-7b", arch_type="vlm", n_layers=32, d_model=4096,
    vocab=32000, block_pattern=("attn",), d_ff=14336, mlp_act="silu",
    attn=AttnConfig(n_heads=32, n_kv=8, head_dim=128, rope_theta=1e6,
                    window=4096),
    vlm_img_tokens=N_IMG_TOKENS, vlm_d_vision=1024,
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf",
)
