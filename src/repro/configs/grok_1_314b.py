"""Grok-1 (314B): 8-expert top-2 MoE decoder [hf:xai-org/grok-1]."""

from repro.configs.base import AttnConfig, MoEConfig, ModelConfig

CONFIG = ModelConfig(
    name="grok-1-314b", arch_type="moe", n_layers=64, d_model=6144,
    vocab=131072, block_pattern=("moe",), d_ff=32768, mlp_act="gelu",
    attn=AttnConfig(n_heads=48, n_kv=8, head_dim=128),
    moe=MoEConfig(n_experts=8, top_k=2, d_ff=32768, capacity_factor=1.25),
    embed_scale=True, logit_softcap=30.0, source="hf:xai-org/grok-1",
)
