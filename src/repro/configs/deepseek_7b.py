"""DeepSeek-7B: llama-arch MHA dense decoder [arXiv:2401.02954]."""

from repro.configs.base import AttnConfig, ModelConfig

CONFIG = ModelConfig(
    name="deepseek-7b", arch_type="dense", n_layers=30, d_model=4096,
    vocab=102400, block_pattern=("attn",), d_ff=11008, mlp_act="silu",
    attn=AttnConfig(n_heads=32, n_kv=32, head_dim=128),
    source="arXiv:2401.02954",
)
