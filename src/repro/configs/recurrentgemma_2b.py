"""RecurrentGemma-2B (Griffin): RG-LRU recurrent blocks + local attention in
a 2:1 pattern, MQA (kv=1), GeGLU MLP [arXiv:2402.19427]."""

from repro.configs.base import AttnConfig, ModelConfig, RGLRUConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b", arch_type="hybrid", n_layers=26, d_model=2560,
    vocab=256000, block_pattern=("rglru", "rglru", "local"), d_ff=7680,
    mlp_act="gelu_tanh", mlp_gated=True,
    attn=AttnConfig(n_heads=10, n_kv=1, head_dim=256, window=2048),
    rglru=RGLRUConfig(lru_width=2560, conv_width=4),
    tie_embeddings=True, embed_scale=True, logit_softcap=30.0,
    source="arXiv:2402.19427",
)
