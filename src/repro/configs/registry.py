"""--arch <id> resolution for every assigned architecture."""

from __future__ import annotations

import importlib

from repro.configs.base import ModelConfig, reduce_for_smoke

_MODULES = {
    "yi-6b": "yi_6b",
    "llava-next-mistral-7b": "llava_next_mistral_7b",
    "minicpm3-4b": "minicpm3_4b",
    "arctic-480b": "arctic_480b",
    "chatglm3-6b": "chatglm3_6b",
    "mamba2-2.7b": "mamba2_2_7b",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "grok-1-314b": "grok_1_314b",
    "whisper-small": "whisper_small",
    "deepseek-7b": "deepseek_7b",
}

ARCH_IDS = tuple(_MODULES)


def get_config(arch: str) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    return mod.CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    return reduce_for_smoke(get_config(arch))
