"""Data pipeline: deterministic synthetic LM streams + memory-mapped corpora,
sharded per host.

Synthetic mode generates structured (learnable) token streams — a noisy
periodic Markov-ish sequence — so integration tests can assert that training
REDUCES loss, not merely that it runs. Memmap mode reads a flat uint16/uint32
token file (the standard packed-corpus format).

Host sharding: every host materializes only its slice of the global batch
(`host_slice`), the standard multi-host JAX input pattern; on this 1-process
container that is the whole batch, but the arithmetic is exercised by tests.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import numpy as np

from repro.configs.base import ModelConfig
from repro.configs.shapes import InputShape


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    kind: str = "synthetic"        # synthetic | memmap
    path: Optional[str] = None     # memmap token file
    period: int = 17               # synthetic structure period
    noise: float = 0.05


def host_slice(global_batch: int, n_hosts: int, host_id: int) -> slice:
    assert global_batch % n_hosts == 0, (global_batch, n_hosts)
    per = global_batch // n_hosts
    return slice(host_id * per, (host_id + 1) * per)


def _synthetic_batch(cfg: DataConfig, step: int, rows: slice) -> np.ndarray:
    """Deterministic learnable stream: tokens follow a periodic progression
    with occasional uniform noise."""
    n = rows.stop - rows.start
    rng = np.random.default_rng(cfg.seed * 1_000_003 + step)
    base = rng.integers(0, cfg.vocab, size=(n, 1), dtype=np.int64)
    t = np.arange(cfg.seq_len, dtype=np.int64)[None, :]
    tokens = (base + t * (1 + (base % cfg.period))) % cfg.vocab
    noise_mask = rng.random((n, cfg.seq_len)) < cfg.noise
    noise = rng.integers(0, cfg.vocab, size=(n, cfg.seq_len), dtype=np.int64)
    tokens = np.where(noise_mask, noise, tokens)
    return tokens.astype(np.int32)


def _memmap_batch(cfg: DataConfig, step: int, rows: slice) -> np.ndarray:
    data = np.memmap(cfg.path, dtype=np.uint16, mode="r")
    n = rows.stop - rows.start
    need = n * (cfg.seq_len + 1)
    start = (step * cfg.global_batch + rows.start) * (cfg.seq_len + 1)
    start = start % max(len(data) - need, 1)
    chunk = np.asarray(data[start: start + need], dtype=np.int32)
    return chunk.reshape(n, cfg.seq_len + 1)[:, : cfg.seq_len] % cfg.vocab


def batch_at(cfg: DataConfig, step: int, *, n_hosts: int = 1,
             host_id: int = 0) -> dict:
    """The (host-local) training batch for a global step: tokens + labels."""
    rows = host_slice(cfg.global_batch, n_hosts, host_id)
    fn = _synthetic_batch if cfg.kind == "synthetic" else _memmap_batch
    tokens = fn(cfg, step, rows)
    return {"tokens": tokens, "labels": tokens}


def iterate(cfg: DataConfig, steps: int, **kw) -> Iterator[dict]:
    for s in range(steps):
        yield batch_at(cfg, s, **kw)


def data_config_for(model_cfg: ModelConfig, shape: InputShape,
                    **kw) -> DataConfig:
    seq = shape.seq_len
    if model_cfg.vlm_img_tokens:
        seq = max(seq - model_cfg.vlm_img_tokens, 8)
    return DataConfig(vocab=model_cfg.vocab, seq_len=seq,
                      global_batch=shape.global_batch, **kw)
