"""Serving engine: batched prefill + greedy/temperature decode loops.

The jitted step functions are shared with the dry-run (launch/dryrun.py
lowers exactly these); the Engine adds the host-side loop, sampling, and a
simple batched-request front end used by examples/serve_batched.py.
"""

from __future__ import annotations

import contextlib
import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.transformer import Batch, Model


@dataclasses.dataclass
class EngineConfig:
    max_seq: int = 1024
    temperature: float = 0.0          # 0 => greedy
    long_context: bool = False        # use the SWA long-context variant
    kv_dtype: str = "native"          # "int8": quantized KV cache


class Engine:
    def __init__(self, model: Model, params, cfg: EngineConfig | None = None,
                 *, meter=None, tracer=None, telemetry=None, monitor=None):
        """`meter` (obs.meter.StepMeter) / `tracer` (obs.trace.TraceWriter)
        optionally instrument the host loop: a "prefill" span plus one span
        and one meter step per decode step. `telemetry`
        (obs.telemetry.TelemetryWriter) streams one step record per decode
        step, and `monitor` (obs.detect.HealthMonitor) watches the decode
        step times for sustained drift (a step-only stream: only the generic
        step_time_drift alarm is reachable — there is no bucket model on the
        decode path). All of them need the per-step blocking `meter`
        provides — leave everything None on the fast path."""
        self.model = model
        self.params = params
        self.cfg = cfg or EngineConfig()
        self.meter = meter
        self.tracer = tracer
        self.telemetry = telemetry
        self.monitor = monitor
        ctx_kw = {}
        if self.cfg.long_context and model.cfg.arch_type in ("dense", "moe",
                                                             "vlm"):
            ctx_kw["window_override"] = model.cfg.long_context_window
        if self.cfg.kv_dtype != "native":
            ctx_kw["kv_dtype"] = self.cfg.kv_dtype
        self._ctx_kw = ctx_kw
        self._prefill = jax.jit(
            lambda p, b: model.prefill(p, b, self.cfg.max_seq, **ctx_kw))
        self._decode = jax.jit(
            lambda p, c, t, pos: model.decode_step(p, c, t, pos, **ctx_kw))

    def _span(self, name: str):
        if self.tracer is None:
            return contextlib.nullcontext()
        return self.tracer.span(name, cat="serve")

    def _sample(self, logits: jax.Array, key: jax.Array) -> jax.Array:
        if self.cfg.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(
            key, logits / self.cfg.temperature, axis=-1).astype(jnp.int32)

    def generate(self, prompts: np.ndarray, n_new: int, *,
                 img_embeds=None, frame_embeds=None,
                 seed: int = 0) -> np.ndarray:
        """prompts (B, S) int32 -> (B, n_new) generated tokens."""
        B, S = prompts.shape
        batch = Batch(tokens=jnp.asarray(prompts, jnp.int32),
                      img_embeds=None if img_embeds is None
                      else jnp.asarray(img_embeds),
                      frame_embeds=None if frame_embeds is None
                      else jnp.asarray(frame_embeds))
        instrumented = self.meter is not None or self.tracer is not None
        with self._span("prefill"):
            logits, cache, pos = self._prefill(self.params, batch)
            if instrumented:
                jax.block_until_ready(logits)
        if self.model.cfg.vlm_img_tokens and img_embeds is not None:
            pos = pos  # pos already counts image tokens via embed concat
        key = jax.random.PRNGKey(seed)
        out = []
        tok = self._sample(logits, key)
        for i in range(n_new):
            out.append(np.asarray(tok))
            key, sub = jax.random.split(key)
            if self.meter is not None:
                self.meter.start()
            with self._span(f"decode/{i}"):
                logits, cache = self._decode(self.params, cache, tok[:, None],
                                             jnp.int32(pos + i))
                tok = self._sample(logits, sub)
                if instrumented:
                    jax.block_until_ready(tok)
            if self.meter is not None:
                self.meter.update(tokens=B)
                if self.telemetry is not None:
                    self.telemetry.step(step=i, t_step_s=self.meter.last_dt,
                                        tok_s=self.meter.tokens_per_sec)
                if self.tracer is not None:
                    self.tracer.counter(
                        "rates", self.tracer.now_us(),
                        {"tokens_per_sec": self.meter.tokens_per_sec})
                if self.monitor is not None:
                    for a in self.monitor.observe_step(i, self.meter.last_dt):
                        if self.telemetry is not None:
                            self.telemetry.alarm(
                                step=a.step, kind=a.kind, factor=a.factor,
                                level=a.level, rank=a.rank, detail=a.detail)
        return np.stack(out, axis=1)


@dataclasses.dataclass
class Request:
    prompt: np.ndarray           # (S,) int32
    max_new: int
    out: Optional[np.ndarray] = None


def serve_requests(engine: Engine, requests: list, *, pad_id: int = 0):
    """Minimal batched serving: left-pad prompts to a common length, decode
    max(max_new) steps, slice per-request outputs."""
    S = max(len(r.prompt) for r in requests)
    n_new = max(r.max_new for r in requests)
    B = len(requests)
    toks = np.full((B, S), pad_id, np.int32)
    for i, r in enumerate(requests):
        toks[i, S - len(r.prompt):] = r.prompt
    gen = engine.generate(toks, n_new)
    for i, r in enumerate(requests):
        r.out = gen[i, : r.max_new]
    return requests
