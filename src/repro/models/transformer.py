"""The Model facade: embeddings + block pattern (scanned) + head, with
train / prefill / decode entry points for every assigned architecture.

Layer stacking: the repeating block pattern is scanned (`lax.scan`) over
`pattern_repeats` with parameters stacked on a leading dim — this keeps the
HLO small enough to compile 480B-parameter configs against a 512-device mesh
in seconds (see DESIGN.md §6). A non-divisible remainder ("tail") is
unrolled. Smoke tests run the same code with 1-2 repeats on CPU.

Modality frontends are stubs per the assignment: VLMs consume precomputed
patch embeddings (projected into d_model), audio models consume precomputed
frame embeddings; everything from there on is real.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro import compat
from repro.configs.base import ModelConfig
from repro.core import planner as pl
from repro.models import blocks, common


@dataclasses.dataclass(frozen=True)
class Batch:
    """Model inputs. `tokens` (B, S) int32; labels/mask same shape (train).
    img_embeds (B, n_img, d_vision) for VLMs; frame_embeds (B, n_frames,
    d_input) for audio enc-dec."""

    tokens: jax.Array
    labels: Optional[jax.Array] = None
    mask: Optional[jax.Array] = None
    img_embeds: Optional[jax.Array] = None
    frame_embeds: Optional[jax.Array] = None


jax.tree_util.register_dataclass(
    Batch, data_fields=["tokens", "labels", "mask", "img_embeds",
                        "frame_embeds"], meta_fields=[])


class Model:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    # ---------------- parameter definitions ----------------

    def param_defs(self) -> dict:
        cfg = self.cfg
        d = cfg.d_model
        defs: dict = {
            "embed": pl.ParamDef((cfg.vocab, d), pl.K_EMBED, cfg.dtype,
                                 init="scaled", init_scale=0.02),
            "ln_f": blocks.norm_defs(d, cfg),
        }
        if not cfg.tie_embeddings:
            defs["head"] = pl.ParamDef((d, cfg.vocab), pl.K_HEAD, cfg.dtype)
        if cfg.vlm_img_tokens:
            defs["img_proj"] = pl.ParamDef((cfg.vlm_d_vision, d),
                                           pl.K_REPLICATED, cfg.dtype)
        if cfg.learned_positions:
            defs["pos_emb"] = pl.ParamDef((cfg.learned_positions, d),
                                          pl.K_REPLICATED, cfg.dtype,
                                          init="scaled", init_scale=0.02)
        if cfg.encoder is not None:
            enc: dict = {
                "blocks": common.stack_defs(blocks.block_defs("enc", cfg),
                                            cfg.encoder.n_layers),
                "pos": pl.ParamDef((cfg.encoder.n_frames, d), pl.K_REPLICATED,
                                   cfg.dtype, init="scaled", init_scale=0.02),
                "ln_f": blocks.norm_defs(d, cfg),
            }
            if cfg.encoder.d_input != d:
                enc["in_proj"] = pl.ParamDef((cfg.encoder.d_input, d),
                                             pl.K_REPLICATED, cfg.dtype)
            defs["encoder"] = enc
        reps = cfg.pattern_repeats
        if reps > 0:
            defs["blocks"] = {
                f"p{i}_{kind}": common.stack_defs(blocks.block_defs(kind, cfg),
                                                  reps)
                for i, kind in enumerate(cfg.block_pattern)
            }
        if cfg.tail_layers:
            defs["tail"] = {
                f"t{i}_{kind}": blocks.block_defs(kind, cfg)
                for i, kind in enumerate(cfg.tail_layers)
            }
        return defs

    def init(self, key: jax.Array) -> dict:
        return common.init_tree(key, self.param_defs())

    def n_params(self) -> int:
        return common.count_params(self.param_defs())

    # paths whose leaves have a leading stacked (scan) dimension
    @staticmethod
    def stacked_path(path: tuple) -> bool:
        for p in path:
            key = getattr(p, "key", None)
            if key in ("blocks",):
                return True
        return False

    # ---------------- helpers ----------------

    def _ctx(self, enc_out=None, window_override=None, moe_impl="gather",
             kv_chunk=None, kv_dtype="native", mesh=None,
             batch_axes=("data",), fsdp_axes=(),
             wgather_wire="bf16", unroll=False,
             tp_axis=None) -> blocks.BlockCtx:
        return blocks.BlockCtx(cfg=self.cfg, window_override=window_override,
                               enc_out=enc_out, moe_impl=moe_impl,
                               kv_chunk=kv_chunk, kv_dtype=kv_dtype,
                               mesh=mesh, batch_axes=batch_axes,
                               fsdp_axes=fsdp_axes,
                               wgather_wire=wgather_wire, unroll=unroll,
                               tp_axis=tp_axis)

    def _embed(self, params: dict, batch: Batch, *, pos0: int = 0) -> jax.Array:
        cfg = self.cfg
        h = jnp.take(params["embed"], batch.tokens, axis=0)
        if cfg.embed_scale:
            h = h * jnp.sqrt(jnp.array(cfg.d_model, h.dtype))
        if cfg.vlm_img_tokens and batch.img_embeds is not None:
            img = batch.img_embeds.astype(cfg.dtype) @ params["img_proj"]
            h = jnp.concatenate([img, h], axis=1)
        if cfg.learned_positions:
            S = h.shape[1]
            h = h + jax.lax.dynamic_slice_in_dim(params["pos_emb"], pos0, S,
                                                 axis=0)[None]
        return h

    def _encode(self, params: dict, frame_embeds: jax.Array, *,
                unroll: bool = False) -> jax.Array:
        cfg = self.cfg
        p = params["encoder"]
        h = frame_embeds.astype(cfg.dtype)
        if "in_proj" in p:
            h = h @ p["in_proj"]
        h = h + p["pos"][None]
        ctx = self._ctx(unroll=unroll)

        def body(carry, pslice):
            hh, _ = blocks.block_apply("enc", pslice, carry, ctx)
            return hh, None

        h, _ = compat.maybe_scan(body, h, p["blocks"], unroll=unroll)
        return blocks.norm_apply(p["ln_f"], h, cfg)

    def _run_blocks(self, params: dict, h: jax.Array, ctx: blocks.BlockCtx):
        """Scan the pattern repeats, then the tail. Returns (h, aux_total)."""
        cfg = self.cfg
        aux0 = jnp.zeros((), jnp.float32)

        if cfg.pattern_repeats > 0:
            stacked = tuple(params["blocks"][f"p{i}_{k}"]
                            for i, k in enumerate(cfg.block_pattern))

            def body(carry, pslices):
                hh, aux = carry
                for kind, ps in zip(cfg.block_pattern, pslices):
                    hh, a = blocks.block_apply(kind, ps, hh, ctx)
                    aux = aux + a
                return (hh, aux), None

            if cfg.remat:
                body = jax.checkpoint(body)
            # unroll: partial-manual shard_map regions on JAX 0.4.x cannot
            # hold a scan loop (compat.PARTIAL_MANUAL_SCAN_OK)
            (h, aux0), _ = compat.maybe_scan(body, (h, aux0), stacked,
                                             unroll=ctx.unroll)

        for i, kind in enumerate(cfg.tail_layers):
            h, a = blocks.block_apply(kind, params["tail"][f"t{i}_{kind}"], h,
                                      ctx)
            aux0 = aux0 + a
        return h, aux0

    def _head(self, params: dict, h: jax.Array) -> jax.Array:
        cfg = self.cfg
        h = blocks.norm_apply(params["ln_f"], h, cfg)
        w = (params["embed"].T if cfg.tie_embeddings else params["head"])
        logits = h @ w
        if cfg.logit_softcap:
            c = cfg.logit_softcap
            logits = jnp.tanh(logits / c) * c
        return logits

    # ---------------- entry points ----------------

    def forward(self, params: dict, batch: Batch, **ctx_kw) -> jax.Array:
        """Full-sequence logits (training / evaluation)."""
        enc_out = None
        if self.cfg.encoder is not None:
            enc_out = self._encode(params, batch.frame_embeds,
                                   unroll=ctx_kw.get("unroll", False))
        ctx = self._ctx(enc_out=enc_out, **ctx_kw)
        h = self._embed(params, batch)
        h, self._last_aux = self._run_blocks(params, h, ctx)
        return self._head(params, h)

    def loss(self, params: dict, batch: Batch, **ctx_kw) -> jax.Array:
        logits = self.forward(params, batch, **ctx_kw)
        cfg = self.cfg
        if cfg.vlm_img_tokens and batch.img_embeds is not None:
            logits = logits[:, batch.img_embeds.shape[1]:]
        loss = common.softmax_xent(logits[:, :-1], batch.labels[:, 1:],
                                   None if batch.mask is None
                                   else batch.mask[:, 1:])
        if self.cfg.moe is not None:
            loss = loss + self.cfg.moe.router_aux_weight * self._last_aux
        return loss

    # ---------------- serving ----------------

    def init_cache(self, batch: int, max_seq: int, **ctx_kw) -> dict:
        cfg = self.cfg
        ctx = self._ctx(**ctx_kw)
        cache: dict = {}
        if cfg.pattern_repeats > 0:
            cache["blocks"] = {}
            for i, kind in enumerate(cfg.block_pattern):
                one = blocks.block_init_cache(kind, cfg, batch, max_seq, ctx)
                cache["blocks"][f"p{i}_{kind}"] = jax.tree.map(
                    lambda x: jnp.broadcast_to(
                        x[None], (cfg.pattern_repeats,) + x.shape), one)
        if cfg.tail_layers:
            cache["tail"] = {
                f"t{i}_{kind}": blocks.block_init_cache(kind, cfg, batch,
                                                        max_seq, ctx)
                for i, kind in enumerate(cfg.tail_layers)
            }
        return cache

    def prefill(self, params: dict, batch: Batch, max_seq: int, **ctx_kw):
        """Consume the prompt; return (last-token logits, cache, prompt_len).

        The cache is laid out for `decode_step`: windowed blocks get ring
        buffers, full-attention blocks get max_seq slots.
        """
        cfg = self.cfg
        enc_out = None
        if cfg.encoder is not None:
            enc_out = self._encode(params, batch.frame_embeds)
        ctx = self._ctx(enc_out=enc_out, **ctx_kw)
        h = self._embed(params, batch)
        S = h.shape[1]
        cache: dict = {}

        def pad_cache(kind, c):
            """Grow prompt-length K/V buffers to max_seq slots."""
            def grow(x):
                if x.ndim >= 2 and x.shape[1] == S and kind != "ssm":
                    pad = [(0, 0)] * x.ndim
                    pad[1] = (0, max(0, max_seq - S))
                    return jnp.pad(x, pad)
                return x
            if kind in ("attn", "local", "moe", "mla", "cross"):
                w = (ctx.window_for(kind) if kind != "mla"
                     else ctx.window_override)
                if kind == "cross":
                    return {"self": jax.tree.map(grow, c["self"]),
                            "cross": c["cross"]}
                if not w or w >= max_seq:
                    return jax.tree.map(grow, c)
            return c

        if cfg.pattern_repeats > 0:
            stacked = tuple(params["blocks"][f"p{i}_{k}"]
                            for i, k in enumerate(cfg.block_pattern))

            def body(carry, pslices):
                hh = carry
                caches = []
                for kind, ps in zip(cfg.block_pattern, pslices):
                    c = blocks.block_prefill_cache(kind, ps, hh, cfg, ctx)
                    caches.append(pad_cache(kind, c))
                    hh, _ = blocks.block_apply(kind, ps, hh, ctx)
                return hh, tuple(caches)

            h, stacked_caches = jax.lax.scan(body, h, stacked)
            cache["blocks"] = {
                f"p{i}_{kind}": stacked_caches[i]
                for i, kind in enumerate(cfg.block_pattern)
            }
        if cfg.tail_layers:
            cache["tail"] = {}
            for i, kind in enumerate(cfg.tail_layers):
                ps = params["tail"][f"t{i}_{kind}"]
                c = blocks.block_prefill_cache(kind, ps, h, cfg, ctx)
                cache["tail"][f"t{i}_{kind}"] = pad_cache(kind, c)
                h, _ = blocks.block_apply(kind, ps, h, ctx)
        logits = self._head(params, h[:, -1:, :])
        return logits[:, 0, :], cache, S

    def decode_step(self, params: dict, cache: dict, token: jax.Array,
                    pos: jax.Array, **ctx_kw):
        """One-token decode. token (B, 1) int32, pos scalar int32 (number of
        tokens already in the cache). Returns (logits (B, V), new cache)."""
        cfg = self.cfg
        ctx = self._ctx(**ctx_kw)
        h = jnp.take(params["embed"], token, axis=0)
        if cfg.embed_scale:
            h = h * jnp.sqrt(jnp.array(cfg.d_model, h.dtype))
        if cfg.learned_positions:
            h = h + jax.lax.dynamic_slice_in_dim(
                params["pos_emb"], pos, 1, axis=0)[None]
        new_cache: dict = {"blocks": {}, "tail": {}}

        if cfg.pattern_repeats > 0:
            stacked_p = tuple(params["blocks"][f"p{i}_{k}"]
                              for i, k in enumerate(cfg.block_pattern))
            stacked_c = tuple(cache["blocks"][f"p{i}_{k}"]
                              for i, k in enumerate(cfg.block_pattern))

            def body(carry, xs):
                hh = carry
                pslices, cslices = xs
                outs = []
                for kind, ps, cs in zip(cfg.block_pattern, pslices, cslices):
                    hh, c2 = blocks.block_decode(kind, ps, hh, cs, pos, ctx)
                    outs.append(c2)
                return hh, tuple(outs)

            h, new_stacked = jax.lax.scan(body, h, (stacked_p, stacked_c))
            new_cache["blocks"] = {
                f"p{i}_{kind}": new_stacked[i]
                for i, kind in enumerate(cfg.block_pattern)
            }
        if cfg.tail_layers:
            for i, kind in enumerate(cfg.tail_layers):
                key = f"t{i}_{kind}"
                h, c2 = blocks.block_decode(kind, params["tail"][key], h,
                                            cache["tail"][key], pos, ctx)
                new_cache["tail"][key] = c2
        logits = self._head(params, h)
        return logits[:, 0, :], new_cache


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg)
