"""Attention mixers: GQA/MHA (full + sliding-window) and MLA.

Each mixer exposes:
  <name>_defs(cfg)                          -> ParamDef dict
  <name>_apply(p, x, cfg, *, pos0, window)  -> y            (train / prefill)
  <name>_prefill_cache(p, x, cfg, ...)      -> cache pieces
  <name>_decode(p, x1, cache, pos, cfg)     -> (y1, cache)  (one new token)

Caches are plain dicts of arrays so they stack cleanly under lax.scan and
shard via the planner's cache specs. Sliding-window caches are ring buffers
of exactly `window` slots; keys are roped at write time so no positional
reconstruction is needed at read time.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import AttnConfig, MLAConfig
from repro.core import collectives as cl
from repro.core import planner as pl
from repro.models import common


# =============================== GQA =========================================

def gqa_defs(d_model: int, a: AttnConfig, dtype) -> dict:
    H, KV, hd = a.n_heads, a.n_kv, a.head_dim
    return {
        "wq": pl.ParamDef((d_model, H * hd), pl.K_PROJ_IN, dtype),
        "wk": pl.ParamDef((d_model, KV * hd), pl.K_PROJ_IN, dtype),
        "wv": pl.ParamDef((d_model, KV * hd), pl.K_PROJ_IN, dtype),
        "wo": pl.ParamDef((H * hd, d_model), pl.K_PROJ_OUT, dtype),
    }


def _split_heads(x, n, hd):
    return x.reshape(*x.shape[:-1], n, hd)


def _sdpa(q, k, v, mask) -> jax.Array:
    """q (B,Q,H,hd), k/v (B,K,H,hd), mask (Q,K) or (B,Q,K) bool."""
    hd = q.shape[-1]
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32)
    scores = scores / math.sqrt(hd)
    if mask is not None:
        if mask.ndim == 2:
            mask = mask[None, None]
        else:
            mask = mask[:, None]
        scores = jnp.where(mask, scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", w, v)


def _repeat_kv(k, n_heads):
    return jnp.repeat(k, n_heads // k.shape[-2], axis=-2) \
        if k.shape[-2] != n_heads else k


def chunked_sdpa(q, k, v, *, causal: bool = True, window: int | None = None,
                 q_offset: int = 0, kv_chunk: int = 1024,
                 scale: float | None = None) -> jax.Array:
    """Online-softmax (flash-style) attention: scans KV in chunks so the
    (Sq, Sk) score matrix never materializes -- O(Sq * kv_chunk) live memory
    instead of O(Sq * Sk). Numerically identical to _sdpa (tests assert).

    q (B,Sq,H,D); k/v (B,Sk,H,D) with heads already repeated. This is the
    beyond-paper memory optimization for the 32k prefill shapes
    (EXPERIMENTS.md §Perf): a TPU-native reformulation (VMEM-sized KV tiles,
    running max/denominator in f32) of the attention the paper-era stack
    materialized.
    """
    B, Sq, H, D = q.shape
    Sk = k.shape[1]
    Dv = v.shape[-1]
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    c = min(kv_chunk, Sk)
    pad = (-Sk) % c
    if pad:
        zk = jnp.zeros((B, pad) + k.shape[2:], k.dtype)
        k = jnp.concatenate([k, zk], axis=1)
        v = jnp.concatenate([v, jnp.zeros((B, pad) + v.shape[2:], v.dtype)],
                            axis=1)
    nk = k.shape[1] // c
    kc = k.reshape(B, nk, c, H, D).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, nk, c, H, Dv).transpose(1, 0, 2, 3, 4)
    q_pos = jnp.arange(Sq) + q_offset

    def body(carry, inp):
        m, l, acc = carry
        j, kj, vj = inp
        k_pos = j * c + jnp.arange(c)
        s = jnp.einsum("bqhd,bkhd->bhqk", q, kj).astype(jnp.float32) * scale
        valid = k_pos[None, :] <= Sk - 1
        if causal:
            valid = valid & (k_pos[None, :] <= q_pos[:, None])
        if window is not None:
            valid = valid & (k_pos[None, :] > q_pos[:, None] - window)
        s = jnp.where(valid[None, None], s, -1e30)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bhqk,bkhd->bqhd", p.astype(q.dtype), vj)
        acc_new = acc * corr.transpose(0, 2, 1)[..., None].astype(acc.dtype) \
            + pv
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, H, Sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, H, Sq), jnp.float32)
    a0 = jnp.zeros((B, Sq, H, Dv), q.dtype)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0),
                                  (jnp.arange(nk), kc, vc))
    denom = jnp.maximum(l, 1e-30).transpose(0, 2, 1)[..., None]
    return (acc.astype(jnp.float32) / denom).astype(q.dtype)


def gqa_apply(p: dict, x: jax.Array, a: AttnConfig, *, pos0: int = 0,
              window: int | None = None, mask: jax.Array | None = None,
              kv_override=None, kv_chunk: int | None = None,
              tp_axis: str | None = None) -> jax.Array:
    """Full forward over a sequence (training / prefill / encoder).

    kv_override: (k, v) for cross-attention (whisper decoder).

    tp_axis: head-sharded tensor parallelism — the projections in `p` are
    this rank's head shard (local head counts derived from the shard
    shapes), x enters through the f operator (identity fwd / psum bwd) and
    the out-projection's partial sum leaves through g (psum fwd / identity
    bwd): repro.core.collectives.tp_replicate / tp_psum. Rope and softmax
    are per-head, so the sharded math is exact."""
    B, S, _ = x.shape
    H, KV, hd = a.n_heads, a.n_kv, a.head_dim
    if tp_axis is not None:
        H = p["wq"].shape[-1] // hd
        KV = p["wk"].shape[-1] // hd
        x = cl.tp_replicate(x, tp_axis)
    q = _split_heads(x @ p["wq"], H, hd)
    if kv_override is None:
        k = _split_heads(x @ p["wk"], KV, hd)
        v = _split_heads(x @ p["wv"], KV, hd)
        positions = jnp.arange(S) + pos0
        q = common.apply_rope(q, positions, rotary_frac=a.rotary_frac,
                              theta=a.rope_theta)
        k = common.apply_rope(k, positions, rotary_frac=a.rotary_frac,
                              theta=a.rope_theta)
        if mask is None and a.causal and kv_chunk is None:
            w = window if window is not None else a.window
            mask = common.causal_mask(S, S, q_offset=0, window=w)
    else:
        k, v = kv_override
    k = _repeat_kv(k, H)
    v = _repeat_kv(v, H)
    if kv_chunk is not None and kv_override is None and mask is None:
        w = window if window is not None else a.window
        o = chunked_sdpa(q, k, v, causal=a.causal, window=w, q_offset=pos0,
                         kv_chunk=kv_chunk)
    else:
        o = _sdpa(q, k, v, mask)
    y = o.reshape(B, S, H * hd) @ p["wo"]
    if tp_axis is not None:
        y = cl.tp_psum(y, tp_axis)
    return y


def gqa_cross_kv(p: dict, enc: jax.Array, a: AttnConfig):
    """Precompute cross-attention K/V from encoder output (whisper)."""
    KV, hd = a.n_kv, a.head_dim
    return (_split_heads(enc @ p["wk"], KV, hd),
            _split_heads(enc @ p["wv"], KV, hd))


def _kv_quant(x: jax.Array):
    """Per-(position, head) vector int8 quantization of K/V rows.

    x (..., hd) -> (int8 (..., hd), f16 scale (..., 1)). The C6 idea applied
    to the decode-shape bottleneck: the KV-cache stream is halved (paper's
    low-precision principle on the memory system instead of the wire)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jnp.maximum(amax / 127.0, 1e-8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale.astype(jnp.float16)


def _kv_dequant(q: jax.Array, scale: jax.Array, dtype) -> jax.Array:
    return (q.astype(jnp.float32) * scale.astype(jnp.float32)).astype(dtype)


def gqa_init_cache(batch: int, max_seq: int, a: AttnConfig, dtype,
                   *, window: int | None = None,
                   kv_dtype: str = "native") -> dict:
    slots = min(max_seq, window) if window else max_seq
    KV, hd = a.n_kv, a.head_dim
    if kv_dtype == "int8":
        return {"k": jnp.zeros((batch, slots, KV, hd), jnp.int8),
                "v": jnp.zeros((batch, slots, KV, hd), jnp.int8),
                "k_s": jnp.zeros((batch, slots, KV, 1), jnp.float16),
                "v_s": jnp.zeros((batch, slots, KV, 1), jnp.float16)}
    return {"k": jnp.zeros((batch, slots, KV, hd), dtype),
            "v": jnp.zeros((batch, slots, KV, hd), dtype)}


def gqa_prefill_cache(p: dict, x: jax.Array, a: AttnConfig, *,
                      window: int | None = None,
                      kv_dtype: str = "native") -> dict:
    """K/V for the whole prompt (ring-compacted if windowed)."""
    KV, hd = a.n_kv, a.head_dim
    S = x.shape[1]
    k = _split_heads(x @ p["wk"], KV, hd)
    v = _split_heads(x @ p["wv"], KV, hd)
    k = common.apply_rope(k, jnp.arange(S), rotary_frac=a.rotary_frac,
                          theta=a.rope_theta)
    if window and S > window:
        # keep the last `window` positions, laid out at their ring slots
        keep_k, keep_v = k[:, -window:], v[:, -window:]
        slot = (jnp.arange(S - window, S)) % window
        k = jnp.zeros_like(keep_k).at[:, slot].set(keep_k)
        v = jnp.zeros_like(keep_v).at[:, slot].set(keep_v)
    if kv_dtype == "int8":
        kq, ks = _kv_quant(k)
        vq, vs = _kv_quant(v)
        return {"k": kq, "v": vq, "k_s": ks, "v_s": vs}
    return {"k": k, "v": v}


def gqa_decode(p: dict, x1: jax.Array, cache: dict, pos: jax.Array,
               a: AttnConfig, *, window: int | None = None):
    """One-token decode. x1 (B,1,d); pos scalar int32 (current length)."""
    B = x1.shape[0]
    H, KV, hd = a.n_heads, a.n_kv, a.head_dim
    slots = cache["k"].shape[1]
    quantized = "k_s" in cache
    q = _split_heads(x1 @ p["wq"], H, hd)
    k1 = _split_heads(x1 @ p["wk"], KV, hd)
    v1 = _split_heads(x1 @ p["wv"], KV, hd)
    posv = jnp.full((1,), pos)
    q = common.apply_rope(q, posv, rotary_frac=a.rotary_frac, theta=a.rope_theta)
    k1 = common.apply_rope(k1, posv, rotary_frac=a.rotary_frac, theta=a.rope_theta)
    write = pos % slots if window else pos
    if quantized:
        k1q, k1s = _kv_quant(k1)
        v1q, v1s = _kv_quant(v1)
        cache2 = {
            "k": jax.lax.dynamic_update_slice_in_dim(cache["k"], k1q, write,
                                                     axis=1),
            "v": jax.lax.dynamic_update_slice_in_dim(cache["v"], v1q, write,
                                                     axis=1),
            "k_s": jax.lax.dynamic_update_slice_in_dim(cache["k_s"], k1s,
                                                       write, axis=1),
            "v_s": jax.lax.dynamic_update_slice_in_dim(cache["v_s"], v1s,
                                                       write, axis=1),
        }
        k = _kv_dequant(cache2["k"], cache2["k_s"], x1.dtype)
        v = _kv_dequant(cache2["v"], cache2["v_s"], x1.dtype)
    else:
        k = jax.lax.dynamic_update_slice_in_dim(cache["k"], k1, write, axis=1)
        v = jax.lax.dynamic_update_slice_in_dim(cache["v"], v1, write, axis=1)
        cache2 = {"k": k, "v": v}
    idx = jnp.arange(slots)
    if window:
        # ring buffer: once full, every slot holds one of the last `slots`
        # positions; before that only slots <= pos are written.
        valid = jnp.where(pos >= slots, jnp.ones_like(idx, dtype=bool),
                          idx <= pos)
    else:
        valid = idx <= pos
    o = _sdpa(q, _repeat_kv(k, H), _repeat_kv(v, H), valid[None, None, :])
    y = o.reshape(B, 1, H * hd) @ p["wo"]
    return y, cache2


def gqa_decode_cross(p: dict, x1: jax.Array, cross_kv: dict,
                     a: AttnConfig) -> jax.Array:
    """Cross-attention for one decoder token against fixed encoder K/V."""
    B = x1.shape[0]
    H, hd = a.n_heads, a.head_dim
    q = _split_heads(x1 @ p["wq"], H, hd)
    k, v = _repeat_kv(cross_kv["k"], H), _repeat_kv(cross_kv["v"], H)
    o = _sdpa(q, k, v, None)
    return o.reshape(B, 1, H * hd) @ p["wo"]


# =============================== MLA =========================================

def mla_defs(d_model: int, m: MLAConfig, dtype) -> dict:
    H = m.n_heads
    qk = m.qk_nope_dim + m.qk_rope_dim
    return {
        "w_dq": pl.ParamDef((d_model, m.q_lora_rank), pl.K_REPLICATED, dtype),
        "q_norm": pl.ParamDef((m.q_lora_rank,), pl.K_NORM, dtype, init="ones"),
        "w_uq": pl.ParamDef((m.q_lora_rank, H * qk), pl.K_PROJ_IN, dtype),
        "w_dkv": pl.ParamDef((d_model, m.kv_lora_rank + m.qk_rope_dim),
                             pl.K_REPLICATED, dtype),
        "kv_norm": pl.ParamDef((m.kv_lora_rank,), pl.K_NORM, dtype, init="ones"),
        "w_uk": pl.ParamDef((m.kv_lora_rank, H * m.qk_nope_dim), pl.K_PROJ_IN,
                            dtype),
        "w_uv": pl.ParamDef((m.kv_lora_rank, H * m.v_head_dim), pl.K_PROJ_IN,
                            dtype),
        "wo": pl.ParamDef((H * m.v_head_dim, d_model), pl.K_PROJ_OUT, dtype),
    }


def _mla_qkv(p, x, m: MLAConfig, pos0: int):
    """Shared q / latent computation for a full sequence."""
    B, S, _ = x.shape
    H = m.n_heads
    cq = common.rmsnorm(x @ p["w_dq"], p["q_norm"])
    q = (cq @ p["w_uq"]).reshape(B, S, H, m.qk_nope_dim + m.qk_rope_dim)
    q_nope, q_pe = q[..., : m.qk_nope_dim], q[..., m.qk_nope_dim:]
    ckv_full = x @ p["w_dkv"]
    ckv, kpe = (ckv_full[..., : m.kv_lora_rank],
                ckv_full[..., m.kv_lora_rank:])
    ckv = common.rmsnorm(ckv, p["kv_norm"])
    positions = jnp.arange(S) + pos0
    q_pe = common.apply_rope(q_pe, positions, theta=m.rope_theta)
    kpe = common.apply_rope(kpe[..., None, :], positions,
                            theta=m.rope_theta)[..., 0, :]
    return q_nope, q_pe, ckv, kpe


def mla_apply(p: dict, x: jax.Array, m: MLAConfig, *, pos0: int = 0,
              window: int | None = None,
              kv_chunk: int | None = None) -> jax.Array:
    B, S, _ = x.shape
    H = m.n_heads
    q_nope, q_pe, ckv, kpe = _mla_qkv(p, x, m, pos0)
    k_nope = (ckv @ p["w_uk"]).reshape(B, S, H, m.qk_nope_dim)
    v = (ckv @ p["w_uv"]).reshape(B, S, H, m.v_head_dim)
    if kv_chunk is not None:
        # fold the decoupled-RoPE component into the head dim and reuse the
        # online-softmax kernel path
        q_cat = jnp.concatenate([q_nope, q_pe], axis=-1)
        k_cat = jnp.concatenate(
            [k_nope, jnp.broadcast_to(kpe[:, :, None, :],
                                      (B, S, H, m.qk_rope_dim))], axis=-1)
        o = chunked_sdpa(q_cat, k_cat, v, causal=True, window=window,
                         q_offset=pos0, kv_chunk=kv_chunk,
                         scale=1.0 / math.sqrt(m.qk_nope_dim + m.qk_rope_dim))
        return o.reshape(B, S, H * m.v_head_dim) @ p["wo"]
    scores = (jnp.einsum("bqhd,bkhd->bhqk", q_nope, k_nope)
              + jnp.einsum("bqhd,bkd->bhqk", q_pe, kpe)).astype(jnp.float32)
    scores = scores / math.sqrt(m.qk_nope_dim + m.qk_rope_dim)
    mask = common.causal_mask(S, S, window=window)
    scores = jnp.where(mask[None, None], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    o = jnp.einsum("bhqk,bkhd->bqhd", w, v)
    return o.reshape(B, S, H * m.v_head_dim) @ p["wo"]


def mla_init_cache(batch: int, max_seq: int, m: MLAConfig, dtype,
                   *, window: int | None = None) -> dict:
    slots = min(max_seq, window) if window else max_seq
    return {"ckv": jnp.zeros((batch, slots, m.kv_lora_rank), dtype),
            "kpe": jnp.zeros((batch, slots, m.qk_rope_dim), dtype)}


def mla_prefill_cache(p: dict, x: jax.Array, m: MLAConfig, *,
                      window: int | None = None) -> dict:
    _, _, ckv, kpe = _mla_qkv(p, x, m, 0)
    if window and x.shape[1] > window:
        S = x.shape[1]
        slot = jnp.arange(S - window, S) % window
        ckv = jnp.zeros_like(ckv[:, :window]).at[:, slot].set(ckv[:, -window:])
        kpe = jnp.zeros_like(kpe[:, :window]).at[:, slot].set(kpe[:, -window:])
    return {"ckv": ckv, "kpe": kpe}


def mla_decode(p: dict, x1: jax.Array, cache: dict, pos: jax.Array,
               m: MLAConfig, *, window: int | None = None):
    """Absorbed-projection MLA decode: attention acts on the latent cache."""
    B = x1.shape[0]
    H = m.n_heads
    slots = cache["ckv"].shape[1]
    cq = common.rmsnorm(x1 @ p["w_dq"], p["q_norm"])
    q = (cq @ p["w_uq"]).reshape(B, 1, H, m.qk_nope_dim + m.qk_rope_dim)
    q_nope, q_pe = q[..., : m.qk_nope_dim], q[..., m.qk_nope_dim:]
    posv = jnp.full((1,), pos)
    q_pe = common.apply_rope(q_pe, posv, theta=m.rope_theta)
    ckv1_full = x1 @ p["w_dkv"]
    ckv1 = common.rmsnorm(ckv1_full[..., : m.kv_lora_rank], p["kv_norm"])
    kpe1 = common.apply_rope(ckv1_full[..., None, m.kv_lora_rank:], posv,
                             theta=m.rope_theta)[..., 0, :]
    write = pos % slots if window else pos
    ckv = jax.lax.dynamic_update_slice_in_dim(cache["ckv"], ckv1, write, axis=1)
    kpe = jax.lax.dynamic_update_slice_in_dim(cache["kpe"], kpe1, write, axis=1)
    # absorb W_uk into the query: q_abs (B,1,H,r)
    w_uk = p["w_uk"].reshape(m.kv_lora_rank, H, m.qk_nope_dim)
    q_abs = jnp.einsum("bqhn,rhn->bqhr", q_nope, w_uk)
    scores = (jnp.einsum("bqhr,bkr->bhqk", q_abs, ckv)
              + jnp.einsum("bqhd,bkd->bhqk", q_pe, kpe)).astype(jnp.float32)
    scores = scores / math.sqrt(m.qk_nope_dim + m.qk_rope_dim)
    idx = jnp.arange(slots)
    if window:
        valid = jnp.where(pos >= slots, jnp.ones_like(idx, dtype=bool),
                          idx <= pos)
    else:
        valid = idx <= pos
    scores = jnp.where(valid[None, None, None, :], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1).astype(x1.dtype)
    ctx = jnp.einsum("bhqk,bkr->bqhr", w, ckv)
    w_uv = p["w_uv"].reshape(m.kv_lora_rank, H, m.v_head_dim)
    o = jnp.einsum("bqhr,rhv->bqhv", ctx, w_uv)
    y = o.reshape(B, 1, H * m.v_head_dim) @ p["wo"]
    return y, {"ckv": ckv, "kpe": kpe}
