"""Mamba-2 SSD (state-space duality) mixer [arXiv:2405.21060].

Training/prefill uses the chunked SSD algorithm: quadratic attention-like
computation inside chunks + a linear recurrence over chunk states. Decode is
the O(1)-state recurrent step, which is what makes the 524k-token decode
shape natural for this family.

Sharding: heads (and the d_inner channel dim) shard over the `model` axis;
B/C projections are group-shared (G=1 here) and replicated, mirroring how
GQA replicates KV heads.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import SSMConfig
from repro.core import planner as pl
from repro.models import common


def ssm_defs(d_model: int, s: SSMConfig, dtype) -> dict:
    d_inner = s.expand * d_model
    H = d_inner // s.head_dim
    GN = s.n_groups * s.d_state
    return {
        "w_z": pl.ParamDef((d_model, d_inner), pl.K_PROJ_IN, dtype),
        "w_x": pl.ParamDef((d_model, d_inner), pl.K_PROJ_IN, dtype),
        "w_B": pl.ParamDef((d_model, GN), pl.K_REPLICATED, dtype),
        "w_C": pl.ParamDef((d_model, GN), pl.K_REPLICATED, dtype),
        "w_dt": pl.ParamDef((d_model, H), pl.K_PROJ_IN, dtype),
        "conv_x": pl.ParamDef((d_inner, s.conv_width), pl.K_CONV_MODEL, dtype,
                              init="scaled", init_scale=0.5),
        "conv_B": pl.ParamDef((GN, s.conv_width), pl.K_REPLICATED, dtype,
                              init="scaled", init_scale=0.5),
        "conv_C": pl.ParamDef((GN, s.conv_width), pl.K_REPLICATED, dtype,
                              init="scaled", init_scale=0.5),
        "A_log": pl.ParamDef((H,), pl.K_VEC_MODEL, jnp.float32, init="zeros"),
        "D": pl.ParamDef((H,), pl.K_VEC_MODEL, jnp.float32, init="ones"),
        "dt_bias": pl.ParamDef((H,), pl.K_VEC_MODEL, jnp.float32, init="zeros"),
        "norm": pl.ParamDef((d_inner,), pl.K_VEC_MODEL, dtype, init="ones"),
        "w_out": pl.ParamDef((d_inner, d_model), pl.K_PROJ_OUT, dtype),
    }


def _causal_conv(x: jax.Array, w: jax.Array) -> jax.Array:
    """Depthwise causal conv: x (B, S, C), w (C, W)."""
    W = w.shape[1]
    xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    parts = [xp[:, i: i + x.shape[1], :] * w[None, None, :, i]
             for i in range(W)]
    return sum(parts)


def _conv_step(x1: jax.Array, conv_state: jax.Array, w: jax.Array):
    """x1 (B, C); conv_state (B, W-1, C) holding the previous inputs."""
    full = jnp.concatenate([conv_state, x1[:, None, :]], axis=1)   # (B, W, C)
    y = jnp.einsum("bwc,cw->bc", full, w)
    return y, full[:, 1:, :]


def _ssd_chunked(xdt, a, Bm, Cm, s: SSMConfig, init_state=None):
    """Chunked SSD.

    xdt (B,S,H,P)  -- inputs already scaled by dt
    a   (B,S,H)    -- log decay per step (dt * A, negative)
    Bm, Cm (B,S,G,N)
    Returns y (B,S,H,P), final_state (B,H,P,N).
    """
    Bsz, S, H, Pd = xdt.shape
    G, N = Bm.shape[2], Bm.shape[3]
    Q = min(s.chunk, S)
    S_orig = S
    if S % Q:
        # pad to a whole number of chunks: zero inputs with zero log-decay
        # (exp(0)=1) leave the final state untouched and the kept outputs
        # unchanged.
        padn = Q - S % Q
        pad = lambda t: jnp.pad(t, [(0, 0), (0, padn)] +
                                [(0, 0)] * (t.ndim - 2))
        xdt, a, Bm, Cm = pad(xdt), pad(a), pad(Bm), pad(Cm)
        S = S + padn
    nc = S // Q
    rep = H // G

    def cs(t):      # (B,S,...) -> (B,nc,Q,...)
        return t.reshape(Bsz, nc, Q, *t.shape[2:])

    x_, a_, B_, C_ = cs(xdt), cs(a.astype(jnp.float32)), cs(Bm), cs(Cm)
    B_h = jnp.repeat(B_, rep, axis=3)          # (B,nc,Q,H,N)
    C_h = jnp.repeat(C_, rep, axis=3)
    acum = jnp.cumsum(a_, axis=2)              # (B,nc,Q,H)

    # --- intra-chunk (quadratic, attention-like) ---
    # L[i,j] = exp(acum_i - acum_j) for j <= i
    diff = acum[:, :, :, None, :] - acum[:, :, None, :, :]   # (B,nc,Qi,Qj,H)
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    L = jnp.where(mask[None, None, :, :, None], jnp.exp(diff), 0.0)
    scores = jnp.einsum("bcqhn,bckhn->bcqkh", C_h, B_h).astype(jnp.float32)
    y_diag = jnp.einsum("bcqkh,bcqkh,bckhp->bcqhp", scores, L,
                        x_.astype(jnp.float32))

    # --- chunk states ---
    decay_to_end = jnp.exp(acum[:, :, -1:, :] - acum)        # (B,nc,Q,H)
    states = jnp.einsum("bcqhn,bcqh,bcqhp->bchnp", B_h,
                        decay_to_end.astype(jnp.float32),
                        x_.astype(jnp.float32))              # (B,nc,H,N,P)
    chunk_decay = jnp.exp(acum[:, :, -1, :])                 # (B,nc,H)

    # --- inter-chunk recurrence over nc (linear scan) ---
    if init_state is None:
        init = jnp.zeros((Bsz, H, N, Pd), jnp.float32)
    else:
        init = init_state.astype(jnp.float32)

    def step(carry, inp):
        st, dc = inp                       # (B,H,N,P), (B,H)
        prev = carry
        new = prev * dc[:, :, None, None] + st
        return new, prev                   # emit state ENTERING the chunk

    xs = (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2))
    final, prev_states = jax.lax.scan(step, init, xs)
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)       # (B,nc,H,N,P)

    # --- inter-chunk contribution ---
    in_decay = jnp.exp(acum)                                 # (B,nc,Q,H)
    y_off = jnp.einsum("bcqhn,bcqh,bchnp->bcqhp", C_h,
                       in_decay.astype(jnp.float32), prev_states)
    y = (y_diag + y_off).reshape(Bsz, S, H, Pd)[:, :S_orig]
    return y.astype(xdt.dtype), final


def ssm_apply(p: dict, u: jax.Array, s: SSMConfig, *, act: str = "silu"):
    """Full-sequence forward. u (B, S, d_model) -> (B, S, d_model)."""
    B_, S, d_model = u.shape
    d_inner = s.expand * d_model
    H = d_inner // s.head_dim
    G, N = s.n_groups, s.d_state
    z = u @ p["w_z"]
    x = _causal_conv(u @ p["w_x"], p["conv_x"])
    Bm = _causal_conv(u @ p["w_B"], p["conv_B"])
    Cm = _causal_conv(u @ p["w_C"], p["conv_C"])
    x, Bm, Cm = jax.nn.silu(x), jax.nn.silu(Bm), jax.nn.silu(Cm)
    dt = jax.nn.softplus((u @ p["w_dt"]).astype(jnp.float32)
                         + p["dt_bias"])                      # (B,S,H)
    A = -jnp.exp(p["A_log"])                                  # (H,)
    xh = x.reshape(B_, S, H, s.head_dim)
    xdt = xh * dt[..., None].astype(xh.dtype)
    a = dt * A
    y, _ = _ssd_chunked(xdt, a, Bm.reshape(B_, S, G, N),
                        Cm.reshape(B_, S, G, N), s)
    y = y + xh * p["D"][None, None, :, None].astype(xh.dtype)
    y = y.reshape(B_, S, d_inner)
    y = common.rmsnorm(y * jax.nn.silu(z), p["norm"])
    return y @ p["w_out"]


def ssm_init_cache(batch: int, d_model: int, s: SSMConfig, dtype) -> dict:
    d_inner = s.expand * d_model
    H = d_inner // s.head_dim
    GN = s.n_groups * s.d_state
    W = s.conv_width
    return {
        "state": jnp.zeros((batch, H, s.d_state, s.head_dim), jnp.float32),
        "conv_x": jnp.zeros((batch, W - 1, d_inner), dtype),
        "conv_B": jnp.zeros((batch, W - 1, GN), dtype),
        "conv_C": jnp.zeros((batch, W - 1, GN), dtype),
    }


def ssm_prefill_cache(p: dict, u: jax.Array, s: SSMConfig) -> dict:
    """Run the chunked scan and keep the final state + conv tails."""
    B_, S, d_model = u.shape
    d_inner = s.expand * d_model
    H = d_inner // s.head_dim
    G, N = s.n_groups, s.d_state
    xr = u @ p["w_x"]
    Br = u @ p["w_B"]
    Cr = u @ p["w_C"]
    x = jax.nn.silu(_causal_conv(xr, p["conv_x"]))
    Bm = jax.nn.silu(_causal_conv(Br, p["conv_B"]))
    Cm = jax.nn.silu(_causal_conv(Cr, p["conv_C"]))
    dt = jax.nn.softplus((u @ p["w_dt"]).astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    xh = x.reshape(B_, S, H, s.head_dim)
    xdt = xh * dt[..., None].astype(xh.dtype)
    _, final = _ssd_chunked(xdt, dt * A, Bm.reshape(B_, S, G, N),
                            Cm.reshape(B_, S, G, N), s)
    W = s.conv_width
    return {
        "state": final,                                      # (B,H,N,P)
        "conv_x": xr[:, -(W - 1):, :],
        "conv_B": Br[:, -(W - 1):, :],
        "conv_C": Cr[:, -(W - 1):, :],
    }


def ssm_decode(p: dict, u1: jax.Array, cache: dict, s: SSMConfig):
    """One recurrent step. u1 (B, 1, d_model)."""
    B_, _, d_model = u1.shape
    d_inner = s.expand * d_model
    H = d_inner // s.head_dim
    G, N = s.n_groups, s.d_state
    u = u1[:, 0, :]
    z = u @ p["w_z"]
    xr, Br, Cr = u @ p["w_x"], u @ p["w_B"], u @ p["w_C"]
    x, conv_x = _conv_step(xr, cache["conv_x"], p["conv_x"])
    Bm, conv_B = _conv_step(Br, cache["conv_B"], p["conv_B"])
    Cm, conv_C = _conv_step(Cr, cache["conv_C"], p["conv_C"])
    x, Bm, Cm = jax.nn.silu(x), jax.nn.silu(Bm), jax.nn.silu(Cm)
    dt = jax.nn.softplus((u @ p["w_dt"]).astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])                                  # (H,)
    xh = x.reshape(B_, H, s.head_dim).astype(jnp.float32)
    dt_ = dt                                                  # (B,H)
    Bh = jnp.repeat(Bm.reshape(B_, G, N), H // G, axis=1)     # (B,H,N)
    Ch = jnp.repeat(Cm.reshape(B_, G, N), H // G, axis=1)
    decay = jnp.exp(dt_ * A)                                  # (B,H)
    state = cache["state"]                                    # (B,H,N,P)
    state = (state * decay[:, :, None, None]
             + jnp.einsum("bhn,bh,bhp->bhnp", Bh.astype(jnp.float32), dt_, xh))
    y = jnp.einsum("bhn,bhnp->bhp", Ch.astype(jnp.float32), state)
    y = y + xh * p["D"][None, :, None]
    y = y.reshape(B_, d_inner).astype(u.dtype)
    y = common.rmsnorm(y * jax.nn.silu(z), p["norm"])
    y1 = (y @ p["w_out"])[:, None, :]
    return y1, {"state": state, "conv_x": conv_x, "conv_B": conv_B,
                "conv_C": conv_C}
