"""Dense MLPs (gated SwiGLU/GeGLU and plain 2-matrix)."""

from __future__ import annotations

import jax

from repro.core import collectives as cl
from repro.core import planner as pl
from repro.models import common


def mlp_defs(d_model: int, d_ff: int, dtype, *, gated: bool = True) -> dict:
    d = {
        "w1": pl.ParamDef((d_model, d_ff), pl.K_PROJ_IN, dtype),
        "w2": pl.ParamDef((d_ff, d_model), pl.K_PROJ_OUT, dtype),
    }
    if gated:
        d["w3"] = pl.ParamDef((d_model, d_ff), pl.K_PROJ_IN, dtype)
    return d


def mlp_apply(p: dict, x: jax.Array, *, act: str = "silu",
              gated: bool = True, tp_axis: str | None = None) -> jax.Array:
    """tp_axis: feature-sharded tensor parallelism — w1/w3 column-sharded and
    w2 row-sharded over the axis; x enters through the f operator and w2's
    partial sum leaves through g (collectives.tp_replicate / tp_psum)."""
    if tp_axis is not None:
        x = cl.tp_replicate(x, tp_axis)
    f = common.act_fn(act)
    h = f(x @ p["w1"])
    if gated:
        h = h * (x @ p["w3"])
    y = h @ p["w2"]
    if tp_axis is not None:
        y = cl.tp_psum(y, tp_axis)
    return y
