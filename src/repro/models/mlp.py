"""Dense MLPs (gated SwiGLU/GeGLU and plain 2-matrix)."""

from __future__ import annotations

import jax

from repro.core import planner as pl
from repro.models import common


def mlp_defs(d_model: int, d_ff: int, dtype, *, gated: bool = True) -> dict:
    d = {
        "w1": pl.ParamDef((d_model, d_ff), pl.K_PROJ_IN, dtype),
        "w2": pl.ParamDef((d_ff, d_model), pl.K_PROJ_OUT, dtype),
    }
    if gated:
        d["w3"] = pl.ParamDef((d_model, d_ff), pl.K_PROJ_IN, dtype)
    return d


def mlp_apply(p: dict, x: jax.Array, *, act: str = "silu",
              gated: bool = True) -> jax.Array:
    f = common.act_fn(act)
    h = f(x @ p["w1"])
    if gated:
        h = h * (x @ p["w3"])
    return h @ p["w2"]
