"""Per-layer block assembly: norm + mixer + MLP/MoE with residuals.

Block kinds (cycled through ModelConfig.block_pattern):
  attn   -- (windowed) causal self-attention + dense MLP
  local  -- sliding-window self-attention + dense MLP (hybrid models)
  mla    -- multi-head latent attention + dense MLP
  moe    -- self-attention + mixture-of-experts MLP (+ optional dense residual)
  ssm    -- Mamba-2 SSD mixer (no separate MLP, as in the source arch)
  rglru  -- RG-LRU recurrent mixer + dense MLP
  enc    -- bidirectional self-attention + MLP (encoder towers)
  cross  -- causal self-attention + cross-attention + MLP (enc-dec decoders)
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import planner as pl
from repro.models import attention as attn_mod
from repro.models import common, mlp, moe, rglru, ssm


# --- norms -------------------------------------------------------------------

def norm_defs(d: int, cfg: ModelConfig) -> dict:
    out = {"scale": pl.ParamDef((d,), pl.K_NORM, cfg.dtype, init="ones")}
    if cfg.norm == "layernorm":
        out["bias"] = pl.ParamDef((d,), pl.K_NORM, cfg.dtype, init="zeros")
    return out


def norm_apply(p: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    if cfg.norm == "layernorm":
        return common.layernorm(x, p["scale"], p["bias"], cfg.norm_eps)
    return common.rmsnorm(x, p["scale"], cfg.norm_eps)


# --- defs --------------------------------------------------------------------

def block_defs(kind: str, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    dt = cfg.dtype
    if kind in ("attn", "local", "enc"):
        return {"ln1": norm_defs(d, cfg),
                "attn": attn_mod.gqa_defs(d, cfg.attn, dt),
                "ln2": norm_defs(d, cfg),
                "mlp": mlp.mlp_defs(d, cfg.d_ff, dt, gated=cfg.mlp_gated)}
    if kind == "mla":
        return {"ln1": norm_defs(d, cfg),
                "mla": attn_mod.mla_defs(d, cfg.mla, dt),
                "ln2": norm_defs(d, cfg),
                "mlp": mlp.mlp_defs(d, cfg.d_ff, dt, gated=cfg.mlp_gated)}
    if kind == "moe":
        return {"ln1": norm_defs(d, cfg),
                "attn": attn_mod.gqa_defs(d, cfg.attn, dt),
                "ln2": norm_defs(d, cfg),
                "moe": moe.moe_defs(d, cfg.moe, dt)}
    if kind == "ssm":
        return {"ln1": norm_defs(d, cfg),
                "ssm": ssm.ssm_defs(d, cfg.ssm, dt)}
    if kind == "rglru":
        return {"ln1": norm_defs(d, cfg),
                "rec": rglru.rglru_defs(d, cfg.rglru, dt),
                "ln2": norm_defs(d, cfg),
                "mlp": mlp.mlp_defs(d, cfg.d_ff, dt, gated=cfg.mlp_gated)}
    if kind == "cross":
        return {"ln1": norm_defs(d, cfg),
                "attn": attn_mod.gqa_defs(d, cfg.attn, dt),
                "ln_x": norm_defs(d, cfg),
                "xattn": attn_mod.gqa_defs(d, cfg.attn, dt),
                "ln2": norm_defs(d, cfg),
                "mlp": mlp.mlp_defs(d, cfg.d_ff, dt, gated=cfg.mlp_gated)}
    raise ValueError(f"unknown block kind {kind!r}")


# --- runtime options passed down from the model ------------------------------

@dataclasses.dataclass(frozen=True)
class BlockCtx:
    cfg: ModelConfig
    window_override: Any = None     # int: force SWA on full-attn blocks
    enc_out: Any = None             # encoder output for cross blocks
    moe_impl: str = "gather"        # gather | ep
    kv_chunk: Any = None            # int: online-softmax attention chunk
    kv_dtype: str = "native"        # int8: quantized GQA KV cache (serving)
    mesh: Any = None                # for moe ep
    batch_axes: tuple = ("data",)
    fsdp_axes: tuple = ()
    wgather_wire: str = "bf16"      # int8: quantized ZeRO weight gathers
    # python-unroll the block scan: required inside partial-manual shard_map
    # regions on JAX 0.4.x (compat.PARTIAL_MANUAL_SCAN_OK)
    unroll: bool = False
    # hybrid execution: activation-exchange axis for tensor-parallel blocks.
    # Whether a given block actually runs sharded is detected from its shard
    # shapes (attn_tp / mlp_tp) — the per-layer hybrid plan leaves fallback
    # layers replicated, and putting f/g psums around full-size weights
    # would multiply their output by the group size.
    tp_axis: Any = None

    def attn_tp(self, p_attn: dict, a) -> Any:
        if self.tp_axis is None:
            return None
        sharded = p_attn["wo"].shape[-2] != a.n_heads * a.head_dim
        return self.tp_axis if sharded else None

    def mlp_tp(self, p_mlp: dict) -> Any:
        if self.tp_axis is None:
            return None
        return self.tp_axis if p_mlp["w2"].shape[-2] != self.cfg.d_ff else None

    def window_for(self, kind: str):
        a = self.cfg.attn
        native = a.window if a is not None else None
        if kind == "local":
            native = native or 2048
        if self.window_override is not None:
            return (min(native, self.window_override) if native
                    else self.window_override)
        return native


# --- train / full-sequence apply ----------------------------------------------

def block_apply(kind: str, p: dict, h: jax.Array, ctx: BlockCtx):
    """Returns (h, aux_loss)."""
    cfg = ctx.cfg
    aux = jnp.zeros((), jnp.float32)
    if kind in ("attn", "local", "moe", "enc"):
        w = ctx.window_for(kind)
        x = norm_apply(p["ln1"], h, cfg)
        causal = kind != "enc"
        a = cfg.attn if causal else dataclasses.replace(cfg.attn, causal=False)
        h = h + attn_mod.gqa_apply(p["attn"], x, a, window=w,
                                   kv_chunk=ctx.kv_chunk,
                                   tp_axis=ctx.attn_tp(p["attn"], a))
        x = norm_apply(p["ln2"], h, cfg)
        if kind == "moe":
            if ctx.moe_impl == "ep":
                y, aux = moe.moe_apply_ep(p["moe"], x, cfg.moe, act=cfg.mlp_act,
                                          mesh=ctx.mesh,
                                          batch_axes=ctx.batch_axes,
                                          fsdp_axes=ctx.fsdp_axes,
                                          wgather_wire=ctx.wgather_wire)
            else:
                y, aux = moe.moe_apply(p["moe"], x, cfg.moe, act=cfg.mlp_act)
        else:
            y = mlp.mlp_apply(p["mlp"], x, act=cfg.mlp_act, gated=cfg.mlp_gated,
                              tp_axis=ctx.mlp_tp(p["mlp"]))
        return h + y, aux
    if kind == "mla":
        x = norm_apply(p["ln1"], h, cfg)
        h = h + attn_mod.mla_apply(p["mla"], x, cfg.mla,
                                   window=ctx.window_override,
                                   kv_chunk=ctx.kv_chunk)
        x = norm_apply(p["ln2"], h, cfg)
        return h + mlp.mlp_apply(p["mlp"], x, act=cfg.mlp_act,
                                 gated=cfg.mlp_gated,
                                 tp_axis=ctx.mlp_tp(p["mlp"])), aux
    if kind == "ssm":
        x = norm_apply(p["ln1"], h, cfg)
        return h + ssm.ssm_apply(p["ssm"], x, cfg.ssm), aux
    if kind == "rglru":
        x = norm_apply(p["ln1"], h, cfg)
        h = h + rglru.rglru_apply(p["rec"], x, cfg.rglru)
        x = norm_apply(p["ln2"], h, cfg)
        return h + mlp.mlp_apply(p["mlp"], x, act=cfg.mlp_act,
                                 gated=cfg.mlp_gated,
                                 tp_axis=ctx.mlp_tp(p["mlp"])), aux
    if kind == "cross":
        x = norm_apply(p["ln1"], h, cfg)
        h = h + attn_mod.gqa_apply(p["attn"], x, cfg.attn,
                                   kv_chunk=ctx.kv_chunk)
        x = norm_apply(p["ln_x"], h, cfg)
        kv = attn_mod.gqa_cross_kv(p["xattn"], ctx.enc_out, cfg.attn)
        h = h + attn_mod.gqa_apply(p["xattn"], x, cfg.attn, kv_override=kv,
                                   mask=None)
        x = norm_apply(p["ln2"], h, cfg)
        return h + mlp.mlp_apply(p["mlp"], x, act=cfg.mlp_act,
                                 gated=cfg.mlp_gated), aux
    raise ValueError(kind)


# --- caches --------------------------------------------------------------------

def block_init_cache(kind: str, cfg: ModelConfig, batch: int, max_seq: int,
                     ctx: BlockCtx):
    dt = cfg.dtype
    if kind in ("attn", "local", "moe"):
        return attn_mod.gqa_init_cache(batch, max_seq, cfg.attn, dt,
                                       window=ctx.window_for(kind),
                                       kv_dtype=ctx.kv_dtype)
    if kind == "mla":
        return attn_mod.mla_init_cache(batch, max_seq, cfg.mla, dt,
                                       window=ctx.window_override)
    if kind == "ssm":
        return ssm.ssm_init_cache(batch, cfg.d_model, cfg.ssm, dt)
    if kind == "rglru":
        return rglru.rglru_init_cache(batch, cfg.rglru, dt)
    if kind == "cross":
        nf = cfg.encoder.n_frames
        kv, hd = cfg.attn.n_kv, cfg.attn.head_dim
        return {"self": attn_mod.gqa_init_cache(batch, max_seq, cfg.attn, dt),
                "cross": {"k": jnp.zeros((batch, nf, kv, hd), dt),
                          "v": jnp.zeros((batch, nf, kv, hd), dt)}}
    raise ValueError(kind)


def block_prefill_cache(kind: str, p: dict, h_in: jax.Array, cfg: ModelConfig,
                        ctx: BlockCtx):
    """Cache after consuming the full prompt. h_in is the block INPUT (the
    same normalized projections the forward pass used)."""
    if kind in ("attn", "local", "moe"):
        x = norm_apply(p["ln1"], h_in, cfg)
        return attn_mod.gqa_prefill_cache(p["attn"], x, cfg.attn,
                                          window=ctx.window_for(kind),
                                          kv_dtype=ctx.kv_dtype)
    if kind == "mla":
        x = norm_apply(p["ln1"], h_in, cfg)
        return attn_mod.mla_prefill_cache(p["mla"], x, cfg.mla,
                                          window=ctx.window_override)
    if kind == "ssm":
        x = norm_apply(p["ln1"], h_in, cfg)
        return ssm.ssm_prefill_cache(p["ssm"], x, cfg.ssm)
    if kind == "rglru":
        x = norm_apply(p["ln1"], h_in, cfg)
        return rglru.rglru_prefill_cache(p["rec"], x, cfg.rglru)
    if kind == "cross":
        x = norm_apply(p["ln1"], h_in, cfg)
        self_c = attn_mod.gqa_prefill_cache(p["attn"], x, cfg.attn)
        k, v = attn_mod.gqa_cross_kv(p["xattn"], ctx.enc_out, cfg.attn)
        return {"self": self_c, "cross": {"k": k, "v": v}}
    raise ValueError(kind)


# --- decode --------------------------------------------------------------------

def block_decode(kind: str, p: dict, h1: jax.Array, cache, pos, ctx: BlockCtx):
    cfg = ctx.cfg
    if kind in ("attn", "local", "moe"):
        w = ctx.window_for(kind)
        x = norm_apply(p["ln1"], h1, cfg)
        y, cache2 = attn_mod.gqa_decode(p["attn"], x, cache, pos, cfg.attn,
                                        window=w)
        h1 = h1 + y
        x = norm_apply(p["ln2"], h1, cfg)
        if kind == "moe":
            y, _ = moe.moe_apply(p["moe"], x, cfg.moe, act=cfg.mlp_act)
        else:
            y = mlp.mlp_apply(p["mlp"], x, act=cfg.mlp_act, gated=cfg.mlp_gated)
        return h1 + y, cache2
    if kind == "mla":
        x = norm_apply(p["ln1"], h1, cfg)
        y, cache2 = attn_mod.mla_decode(p["mla"], x, cache, pos, cfg.mla,
                                        window=ctx.window_override)
        h1 = h1 + y
        x = norm_apply(p["ln2"], h1, cfg)
        return h1 + mlp.mlp_apply(p["mlp"], x, act=cfg.mlp_act,
                                  gated=cfg.mlp_gated), cache2
    if kind == "ssm":
        x = norm_apply(p["ln1"], h1, cfg)
        y, cache2 = ssm.ssm_decode(p["ssm"], x, cache, cfg.ssm)
        return h1 + y, cache2
    if kind == "rglru":
        x = norm_apply(p["ln1"], h1, cfg)
        y, cache2 = rglru.rglru_decode(p["rec"], x, cache, cfg.rglru)
        h1 = h1 + y
        x = norm_apply(p["ln2"], h1, cfg)
        return h1 + mlp.mlp_apply(p["mlp"], x, act=cfg.mlp_act,
                                  gated=cfg.mlp_gated), cache2
    if kind == "cross":
        x = norm_apply(p["ln1"], h1, cfg)
        y, self2 = attn_mod.gqa_decode(p["attn"], x, cache["self"], pos,
                                       cfg.attn)
        h1 = h1 + y
        x = norm_apply(p["ln_x"], h1, cfg)
        h1 = h1 + attn_mod.gqa_decode_cross(p["xattn"], x, cache["cross"],
                                            cfg.attn)
        x = norm_apply(p["ln2"], h1, cfg)
        h1 = h1 + mlp.mlp_apply(p["mlp"], x, act=cfg.mlp_act,
                                gated=cfg.mlp_gated)
        return h1, {"self": self2, "cross": cache["cross"]}
    raise ValueError(kind)
