"""Mixture-of-Experts layers: top-k token-choice routing.

Two dispatch implementations, both capacity-based (GShard semantics, overflow
tokens dropped from the expert path but preserved by the residual):

  * `moe_apply` (baseline, pure GSPMD): sort-based dispatch with static
    shapes — argsort tokens by expert, scatter into an (E, C, d) buffer,
    batched expert matmuls, scatter-add back. Expert weights shard over the
    `model` axis on the expert dim when E divides it, else on d_ff (tensor
    parallel experts). The cross-device token movement is whatever GSPMD
    infers from the gather/scatter — this is the baseline the paper-style
    optimization improves on.

  * `moe_apply_ep` (optimized, shard_map): explicit expert parallelism with
    all_to_all over the model axis — the MLSL-flavored hand-scheduled
    collective data path (see EXPERIMENTS.md §Perf). Requires
    E % model_axis_size == 0 and runs fully manual over the model axis.

Routing math is shared, so both paths are numerically comparable up to token
drop ordering (tests assert equivalence where capacities are loose).
"""

from __future__ import annotations

import dataclasses
import functools
import math

import jax
import jax.numpy as jnp

from repro import compat
from repro.configs.base import MoEConfig
from repro.core import planner as pl
from repro.models import common, mlp


def moe_defs(d_model: int, m: MoEConfig, dtype) -> dict:
    d = {
        "router": pl.ParamDef((d_model, m.n_experts), pl.K_REPLICATED,
                              jnp.float32),
        "w1": pl.ParamDef((m.n_experts, d_model, m.d_ff), pl.K_EXPERT_IN, dtype),
        "w2": pl.ParamDef((m.n_experts, m.d_ff, d_model), pl.K_EXPERT_OUT, dtype),
        "w3": pl.ParamDef((m.n_experts, d_model, m.d_ff), pl.K_EXPERT_IN, dtype),
    }
    if m.dense_residual_ff:
        d["dense"] = mlp.mlp_defs(d_model, m.dense_residual_ff, dtype)
    return d


def capacity(n_tokens: int, m: MoEConfig) -> int:
    c = int(math.ceil(n_tokens * m.top_k * m.capacity_factor / m.n_experts))
    return max(8, ((c + 7) // 8) * 8)     # sublane-aligned


def route(xf: jax.Array, router_w: jax.Array, m: MoEConfig):
    """xf (T, d) -> (weights (T, k), ids (T, k), aux_loss scalar)."""
    logits = (xf.astype(jnp.float32) @ router_w).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    weights, ids = jax.lax.top_k(probs, m.top_k)
    weights = weights / jnp.maximum(jnp.sum(weights, axis=-1, keepdims=True),
                                    1e-9)
    # load-balance auxiliary loss (Switch/GShard): E * sum_e f_e * p_e
    T = xf.shape[0]
    me = jnp.mean(probs, axis=0)
    one_hot = jax.nn.one_hot(ids[:, 0], m.n_experts, dtype=jnp.float32)
    ce = jnp.sum(one_hot, axis=0) / T
    aux = m.n_experts * jnp.sum(me * ce)
    return weights, ids, aux


def _expert_ffn(w1, w2, w3, xe, act: str):
    """xe (E, C, d) -> (E, C, d) with per-expert SwiGLU."""
    f = common.act_fn(act)
    h = f(jnp.einsum("ecd,edf->ecf", xe, w1))
    h = h * jnp.einsum("ecd,edf->ecf", xe, w3)
    return jnp.einsum("ecf,efd->ecd", h, w2)


def _dispatch_indices(ids: jax.Array, m: MoEConfig, cap: int):
    """Sort-based capacity dispatch with static shapes.

    Returns (slot_token (E*C,) token index feeding each expert slot,
             slot_valid (E*C,) bool,
             slot_weight_src (E*C,) index into the flat (T*k,) weight vector).
    """
    T = ids.shape[0]
    flat_e = ids.reshape(-1)                           # (T*k,) expert of slot
    order = jnp.argsort(flat_e, stable=True)           # group by expert
    sorted_e = flat_e[order]
    arange = jnp.arange(T * m.top_k)
    group_start = jnp.searchsorted(sorted_e, jnp.arange(m.n_experts),
                                   side="left")
    pos_in_group = arange - group_start[sorted_e]
    ok = pos_in_group < cap
    dest = jnp.where(ok, sorted_e * cap + pos_in_group, m.n_experts * cap)
    slot_token = jnp.full((m.n_experts * cap + 1,), 0, jnp.int32)
    slot_valid = jnp.zeros((m.n_experts * cap + 1,), bool)
    slot_wsrc = jnp.zeros((m.n_experts * cap + 1,), jnp.int32)
    slot_token = slot_token.at[dest].set((order // m.top_k).astype(jnp.int32))
    slot_valid = slot_valid.at[dest].set(True)
    slot_wsrc = slot_wsrc.at[dest].set(order.astype(jnp.int32))
    return slot_token[:-1], slot_valid[:-1], slot_wsrc[:-1]


def moe_apply(p: dict, x: jax.Array, m: MoEConfig, *, act: str = "silu"):
    """Baseline GSPMD MoE. x (B, S, d) -> (y, aux_loss)."""
    B, S, d = x.shape
    xf = x.reshape(B * S, d)
    T = B * S
    cap = capacity(T, m)
    weights, ids, aux = route(xf, p["router"], m)
    slot_token, slot_valid, slot_wsrc = _dispatch_indices(ids, m, cap)
    xe = xf[slot_token] * slot_valid[:, None].astype(x.dtype)   # (E*C, d)
    xe = xe.reshape(m.n_experts, cap, d)
    ye = _expert_ffn(p["w1"], p["w2"], p["w3"], xe, act)        # (E, C, d)
    yf = ye.reshape(m.n_experts * cap, d)
    w_slot = weights.reshape(-1)[slot_wsrc] * slot_valid.astype(jnp.float32)
    contrib = yf * w_slot[:, None].astype(x.dtype)
    y = jnp.zeros((T, d), x.dtype).at[slot_token].add(contrib)
    y = y.reshape(B, S, d)
    if "dense" in p:
        y = y + mlp.mlp_apply(p["dense"], x, act=act)
    return y, aux


# --- optimized path: explicit expert parallelism over the model axis ---------

def _quantized_gather(w: jax.Array, axis_name: str, concat_axis: int,
                      p_size: int) -> jax.Array:
    """ZeRO weight all-gather with an int8 wire (paper C6 applied to the
    FSDP data path): quantize the local shard blockwise, gather int8 +
    scales, dequantize and reassemble. Halves the dominant collective of
    giant-MoE training (EXPERIMENTS.md §Perf, arctic-480b).

    Gradients use the straight-through estimator: the backward pass is the
    exact vjp of an (unquantized) all-gather — a reduce-scatter of the
    cotangent — because d(round)/dx = 0 would otherwise zero the expert
    weight gradients."""
    from repro.kernels import ops as kops

    def impl(w):
        q, s, meta = kops.quantize(w, block=512, backend="jnp")
        qg = jax.lax.all_gather(q, axis_name, axis=0, tiled=False)
        sg = jax.lax.all_gather(s, axis_name, axis=0, tiled=False)
        parts = [kops.dequantize(qg[i], sg[i], meta).astype(w.dtype)
                 for i in range(p_size)]
        return jnp.concatenate(parts, axis=concat_axis)

    @jax.custom_vjp
    def qg(w):
        return impl(w)

    def fwd(w):
        return impl(w), None

    def bwd(_, g):
        return (jax.lax.psum_scatter(g, axis_name,
                                     scatter_dimension=concat_axis,
                                     tiled=True),)

    qg.defvjp(fwd, bwd)
    return qg(w)


def moe_apply_ep(p: dict, x: jax.Array, m: MoEConfig, *, act: str,
                 mesh: jax.sharding.Mesh, model_axis: str = "model",
                 batch_axes: tuple = ("data",), fsdp_axes: tuple = (),
                 wire_bf16_a2a: bool = False, wgather_wire: str = "bf16"):
    """shard_map all-to-all expert parallelism (paper-style hand scheduling).

    Layout: tokens are batch-sharded over `batch_axes` and replicated over
    the model axis; each model rank takes a 1/ep slice of its local tokens,
    routes them, exchanges token slots with the expert owners via all_to_all,
    runs its local experts, and reverses the exchange. Router weights are
    replicated; expert weights are sharded on the expert dim.
    """
    ep = mesh.shape[model_axis]
    assert m.n_experts % ep == 0, (m.n_experts, ep)
    e_local = m.n_experts // ep
    P = jax.sharding.PartitionSpec
    bspec = batch_axes if len(batch_axes) > 1 else batch_axes[0]

    def local_fn(xl, router_w, w1, w2, w3):
        # xl (b_loc, S, d) replicated over model; w* lead dim e_local.
        if fsdp_axes:
            # ZeRO-3 style: expert weights arrive sharded on d over the batch
            # axes; gather just-in-time before use (int8 wire optional).
            for a in reversed(fsdp_axes):
                if wgather_wire == "int8":
                    psz = compat.axis_size(a)
                    w1 = _quantized_gather(w1, a, 1, psz)
                    w3 = _quantized_gather(w3, a, 1, psz)
                    w2 = _quantized_gather(w2, a, 2, psz)
                else:
                    w1 = jax.lax.all_gather(w1, a, axis=1, tiled=True)
                    w3 = jax.lax.all_gather(w3, a, axis=1, tiled=True)
                    w2 = jax.lax.all_gather(w2, a, axis=2, tiled=True)
        b, S, d = xl.shape
        r = jax.lax.axis_index(model_axis)
        T = b * S
        assert T % ep == 0, (T, ep)
        t_loc = T // ep
        xf = xl.reshape(T, d)
        my = jax.lax.dynamic_slice_in_dim(xf, r * t_loc, t_loc, axis=0)
        weights, ids, aux = route(my, router_w, m)
        cap = capacity(t_loc, m)         # per-source-rank, per-expert capacity
        slot_token, slot_valid, slot_wsrc = _dispatch_indices(ids, m, cap)
        xe = my[slot_token] * slot_valid[:, None].astype(xl.dtype)
        # (E, C, d) -> (ep, e_local*C, d): block j goes to expert-owner rank j
        send = xe.reshape(ep, e_local * cap, d)
        if wire_bf16_a2a:
            send = send.astype(jnp.bfloat16)
        recv = jax.lax.all_to_all(send, model_axis, split_axis=0,
                                  concat_axis=0, tiled=True)
        recv = recv.astype(xl.dtype)
        # recv: (ep * e_local * C, d) == tokens from every source for my experts
        xe_mine = recv.reshape(ep, e_local, cap, d).transpose(1, 0, 2, 3)
        xe_mine = xe_mine.reshape(e_local, ep * cap, d)
        ye = _expert_ffn(w1, w2, w3, xe_mine, act)
        ye = ye.reshape(e_local, ep, cap, d).transpose(1, 0, 2, 3)
        back = ye.reshape(ep, e_local * cap, d)
        if wire_bf16_a2a:
            back = back.astype(jnp.bfloat16)
        got = jax.lax.all_to_all(back, model_axis, split_axis=0,
                                 concat_axis=0, tiled=True)
        got = got.astype(xl.dtype).reshape(m.n_experts * cap, d)
        w_slot = weights.reshape(-1)[slot_wsrc] * slot_valid.astype(jnp.float32)
        contrib = got * w_slot[:, None].astype(xl.dtype)
        y_my = jnp.zeros((t_loc, d), xl.dtype).at[slot_token].add(contrib)
        # reassemble the full local token set across model ranks
        y = jax.lax.all_gather(y_my, model_axis, axis=0, tiled=True)
        aux = jax.lax.pmean(aux, (model_axis,) + tuple(batch_axes))
        return y.reshape(b, S, d), aux

    wspec_in = P(model_axis, fsdp_axes if len(fsdp_axes) > 1 else
                 (fsdp_axes[0] if fsdp_axes else None), None)
    wspec_out = P(model_axis, None,
                  fsdp_axes if len(fsdp_axes) > 1 else
                  (fsdp_axes[0] if fsdp_axes else None))
    fn = compat.shard_map(
        local_fn, mesh=mesh,
        in_specs=(P(bspec, None, None), P(None, None), wspec_in,
                  wspec_out, wspec_in),
        out_specs=(P(bspec, None, None), P()),
        axis_names={model_axis} | set(batch_axes), check_vma=False)
    y, aux = fn(x, p["router"], p["w1"], p["w2"], p["w3"])
    if "dense" in p:
        y = y + mlp.mlp_apply(p["dense"], x, act=act)
    return y, aux
