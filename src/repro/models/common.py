"""Shared model building blocks: init, norms, rotary embeddings, losses."""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.planner import ParamDef


# --- parameter initialization -----------------------------------------------

def init_param(key: jax.Array, pd: ParamDef) -> jax.Array:
    if pd.init == "zeros":
        return jnp.zeros(pd.shape, pd.dtype)
    if pd.init == "ones":
        return jnp.ones(pd.shape, pd.dtype)
    fan_in = pd.shape[-2] if len(pd.shape) >= 2 else pd.shape[-1]
    scale = pd.init_scale if pd.init_scale is not None else 1.0 / math.sqrt(fan_in)
    return (jax.random.normal(key, pd.shape, jnp.float32) * scale).astype(pd.dtype)


def init_tree(key: jax.Array, defs_tree) -> Any:
    leaves, treedef = jax.tree_util.tree_flatten(
        defs_tree, is_leaf=lambda x: isinstance(x, ParamDef))
    keys = jax.random.split(key, len(leaves))
    return jax.tree_util.tree_unflatten(
        treedef, [init_param(k, pd) for k, pd in zip(keys, leaves)])


def abstract_tree(defs_tree, shardings=None) -> Any:
    """ParamDef tree -> ShapeDtypeStruct tree (optionally sharded) for dry-runs."""
    def one(pd, sh=None):
        return jax.ShapeDtypeStruct(pd.shape, pd.dtype, sharding=sh)
    if shardings is None:
        return jax.tree_util.tree_map(one, defs_tree,
                                      is_leaf=lambda x: isinstance(x, ParamDef))
    return jax.tree_util.tree_map(one, defs_tree, shardings,
                                  is_leaf=lambda x: isinstance(x, ParamDef))


def count_params(defs_tree) -> int:
    leaves = jax.tree_util.tree_leaves(
        defs_tree, is_leaf=lambda x: isinstance(x, ParamDef))
    return int(sum(l.size for l in leaves))


def stack_defs(defs_tree, n: int):
    """Add a leading scan dimension of size n to every ParamDef."""
    def one(pd: ParamDef) -> ParamDef:
        return ParamDef(shape=(n,) + pd.shape, kind=pd.kind, dtype=pd.dtype,
                        init=pd.init, init_scale=pd.init_scale)
    return jax.tree_util.tree_map(one, defs_tree,
                                  is_leaf=lambda x: isinstance(x, ParamDef))


# --- norms --------------------------------------------------------------------

def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * scale.astype(jnp.float32)).astype(dt)


def layernorm(x: jax.Array, scale: jax.Array, bias: jax.Array,
              eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


# --- rotary position embeddings ------------------------------------------------

def rope_freqs(head_dim: int, rotary_dim: int, theta: float) -> jax.Array:
    """Inverse frequencies for the rotated prefix of the head dim."""
    assert rotary_dim % 2 == 0
    exponents = jnp.arange(0, rotary_dim, 2, dtype=jnp.float32) / rotary_dim
    del head_dim
    return 1.0 / (theta ** exponents)          # (rotary_dim/2,)


def apply_rope(x: jax.Array, positions: jax.Array, *, rotary_frac: float = 1.0,
               theta: float = 1e4) -> jax.Array:
    """x: (..., seq, heads, head_dim); positions: broadcastable to (..., seq).

    rotary_frac < 1 rotates only the leading fraction of head_dim (partial
    rotary, e.g. ChatGLM's 2D-RoPE halves and GLM/NeoX-style models).
    """
    head_dim = x.shape[-1]
    rot = int(head_dim * rotary_frac)
    rot -= rot % 2
    if rot == 0:
        return x
    inv = rope_freqs(head_dim, rot, theta)                 # (rot/2,)
    ang = positions[..., None].astype(jnp.float32) * inv   # (..., seq, rot/2)
    cos = jnp.cos(ang)[..., None, :]                        # (..., seq, 1, rot/2)
    sin = jnp.sin(ang)[..., None, :]
    xr = x[..., :rot].astype(jnp.float32)
    x1, x2 = xr[..., : rot // 2], xr[..., rot // 2:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return jnp.concatenate([out.astype(x.dtype), x[..., rot:]], axis=-1)


def sinusoidal_positions(n: int, d: int) -> jax.Array:
    pos = jnp.arange(n, dtype=jnp.float32)[:, None]
    div = jnp.exp(jnp.arange(0, d, 2, dtype=jnp.float32) * (-math.log(10000.0) / d))
    pe = jnp.zeros((n, d), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(pos * div))
    pe = pe.at[:, 1::2].set(jnp.cos(pos * div))
    return pe


# --- activations / loss ---------------------------------------------------------

def act_fn(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu,
            "gelu_tanh": lambda x: jax.nn.gelu(x, approximate=True),
            "relu": jax.nn.relu}[name]


def softmax_xent(logits: jax.Array, labels: jax.Array,
                 mask: jax.Array | None = None) -> jax.Array:
    """Mean cross-entropy; logits (..., V) promoted to f32."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        mask = mask.astype(jnp.float32)
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)


def causal_mask(q_len: int, kv_len: int, *, q_offset: int = 0,
                window: int | None = None) -> jax.Array:
    """Boolean (q_len, kv_len) mask; True == attend. Supports sliding window."""
    q_pos = jnp.arange(q_len) + q_offset
    k_pos = jnp.arange(kv_len)
    m = k_pos[None, :] <= q_pos[:, None]
    if window is not None:
        m &= k_pos[None, :] > (q_pos[:, None] - window)
    return m
