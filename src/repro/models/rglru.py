"""RG-LRU recurrent block (RecurrentGemma / Griffin) [arXiv:2402.19427].

The recurrence h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t) with
input-gated decay a_t = exp(-c * softplus(Lambda) * sigmoid(r_t)) is a
first-order linear recurrence, computed over full sequences with
jax.lax.associative_scan (log-depth, shardable) and as an O(1) step at
decode time. Combined with a width-4 causal conv and a gated-GeLU branch as
in the Griffin recurrent block.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import RGLRUConfig
from repro.core import planner as pl
from repro.models.ssm import _causal_conv, _conv_step


def rglru_defs(d_model: int, r: RGLRUConfig, dtype) -> dict:
    w = r.lru_width
    return {
        "w_in": pl.ParamDef((d_model, w), pl.K_PROJ_IN, dtype),
        "w_gate": pl.ParamDef((d_model, w), pl.K_PROJ_IN, dtype),
        "conv": pl.ParamDef((w, r.conv_width), pl.K_CONV_MODEL, dtype,
                            init="scaled", init_scale=0.5),
        # per-channel recurrence parameters (sharded with the channel dim)
        "w_a": pl.ParamDef((w, w), pl.K_REPLICATED, dtype,
                           init="scaled", init_scale=0.02),
        "b_a": pl.ParamDef((w,), pl.K_VEC_MODEL, jnp.float32, init="zeros"),
        "w_i": pl.ParamDef((w, w), pl.K_REPLICATED, dtype,
                           init="scaled", init_scale=0.02),
        "b_i": pl.ParamDef((w,), pl.K_VEC_MODEL, jnp.float32, init="zeros"),
        "lam": pl.ParamDef((w,), pl.K_VEC_MODEL, jnp.float32, init="ones"),
        "w_out": pl.ParamDef((w, d_model), pl.K_PROJ_OUT, dtype),
    }


def _gates(p: dict, x: jax.Array, r: RGLRUConfig):
    """x (..., w) post-conv branch input -> (a, gated_input) in f32."""
    xf = x.astype(jnp.float32)
    rt = jax.nn.sigmoid(xf @ p["w_a"].astype(jnp.float32) + p["b_a"])
    it = jax.nn.sigmoid(xf @ p["w_i"].astype(jnp.float32) + p["b_i"])
    log_a = -r.c_constant * jax.nn.softplus(p["lam"]) * rt
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (it * xf)
    return a, b


def rglru_apply(p: dict, x: jax.Array, r: RGLRUConfig) -> jax.Array:
    """Full-sequence forward. x (B, S, d_model)."""
    u = _causal_conv(x @ p["w_in"], p["conv"])
    a, b = _gates(p, u, r)

    def combine(l, rr):
        a1, b1 = l
        a2, b2 = rr
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    gate = jax.nn.gelu((x @ p["w_gate"]).astype(jnp.float32))
    y = (h * gate).astype(x.dtype)
    return y @ p["w_out"]


def rglru_init_cache(batch: int, r: RGLRUConfig, dtype) -> dict:
    return {
        "h": jnp.zeros((batch, r.lru_width), jnp.float32),
        "conv": jnp.zeros((batch, r.conv_width - 1, r.lru_width), dtype),
    }


def rglru_prefill_cache(p: dict, x: jax.Array, r: RGLRUConfig) -> dict:
    pre = x @ p["w_in"]
    u = _causal_conv(pre, p["conv"])
    a, b = _gates(p, u, r)

    def combine(l, rr):
        a1, b1 = l
        a2, b2 = rr
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return {"h": h[:, -1, :], "conv": pre[:, -(r.conv_width - 1):, :]}


def rglru_decode(p: dict, x1: jax.Array, cache: dict, r: RGLRUConfig):
    """One step. x1 (B, 1, d_model)."""
    x = x1[:, 0, :]
    u, conv = _conv_step(x @ p["w_in"], cache["conv"], p["conv"])
    a, b = _gates(p, u, r)
    h = a * cache["h"] + b
    gate = jax.nn.gelu((x @ p["w_gate"]).astype(jnp.float32))
    y = (h * gate).astype(x.dtype) @ p["w_out"]
    return y[:, None, :], {"h": h, "conv": conv}
