"""Optimizers, built in-tree (no optax dependency): SGD-momentum, AdamW, and
the layerwise large-batch optimizers LARS/LAMB.

Large-batch training is a pillar of the paper's scaling argument (C3: the
compute-to-communication ratio is proportional to the mini-batch, so
efficient scale-out REQUIRES large global batches, which in turn require
layerwise-adaptive optimizers to retain accuracy -- paper refs [6, 11, 18]).

All optimizers share one interface:
    opt = adamw(lr=..., ...)
    state = opt.init(params)
    new_params, new_state = opt.update(grads, state, params, step)
`lr` may be a float or a schedule fn step -> float (see repro.optim.schedules).
`state_dtype` lets giant models keep moments in bf16 (memory-driven; the
planner's HBM budget reasoning in DESIGN.md §4).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable
    update: Callable          # (grads, state, params, step) -> (params, state)
    state_bytes_per_param: float


def _lr_at(lr, step):
    return lr(step) if callable(lr) else jnp.asarray(lr, jnp.float32)


def _cast(x, dtype):
    return x.astype(dtype) if dtype is not None else x


def _global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(l.astype(jnp.float32) ** 2) for l in leaves))


def clip_by_global_norm(grads, max_norm: float):
    gn = _global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree_util.tree_map(
        lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), gn


def sgd_momentum(lr, momentum: float = 0.9, weight_decay: float = 0.0,
                 nesterov: bool = False, state_dtype=jnp.float32) -> Optimizer:
    def init(params):
        return {"mu": jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, state_dtype), params)}

    def update(grads, state, params, step):
        lr_t = _lr_at(lr, step)

        def one(g, mu, p):
            g = g.astype(jnp.float32)
            if weight_decay:
                g = g + weight_decay * p.astype(jnp.float32)
            mu_new = momentum * mu.astype(jnp.float32) + g
            d = g + momentum * mu_new if nesterov else mu_new
            return ((p.astype(jnp.float32) - lr_t * d).astype(p.dtype),
                    _cast(mu_new, state_dtype))

        flat_g, treedef = jax.tree_util.tree_flatten(grads)
        flat_m = treedef.flatten_up_to(state["mu"])
        flat_p = treedef.flatten_up_to(params)
        outs = [one(g, m, p) for g, m, p in zip(flat_g, flat_m, flat_p)]
        unf = lambda i: jax.tree_util.tree_unflatten(
            treedef, [o[i] for o in outs])
        return unf(0), {"mu": unf(1)}

    return Optimizer(init, update,
                     state_bytes_per_param=jnp.dtype(state_dtype).itemsize)


def _adam_moments(g, m, v, b1, b2):
    g = g.astype(jnp.float32)
    m_new = b1 * m.astype(jnp.float32) + (1 - b1) * g
    v_new = b2 * v.astype(jnp.float32) + (1 - b2) * g * g
    return m_new, v_new


def adamw(lr, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.1, state_dtype=jnp.float32) -> Optimizer:
    def init(params):
        z = lambda p: jnp.zeros(p.shape, state_dtype)
        return {"m": jax.tree_util.tree_map(z, params),
                "v": jax.tree_util.tree_map(z, params)}

    def update(grads, state, params, step):
        lr_t = _lr_at(lr, step)
        c1 = 1 - b1 ** (step.astype(jnp.float32) + 1)
        c2 = 1 - b2 ** (step.astype(jnp.float32) + 1)

        def one(g, m, v, p):
            m_new, v_new = _adam_moments(g, m, v, b1, b2)
            upd = (m_new / c1) / (jnp.sqrt(v_new / c2) + eps)
            upd = upd + weight_decay * p.astype(jnp.float32)
            return ((p.astype(jnp.float32) - lr_t * upd).astype(p.dtype),
                    _cast(m_new, state_dtype), _cast(v_new, state_dtype))

        flat_g, treedef = jax.tree_util.tree_flatten(grads)
        flat_m = treedef.flatten_up_to(state["m"])
        flat_v = treedef.flatten_up_to(state["v"])
        flat_p = treedef.flatten_up_to(params)
        outs = [one(g, m, v, p) for g, m, v, p
                in zip(flat_g, flat_m, flat_v, flat_p)]
        unf = lambda i: jax.tree_util.tree_unflatten(
            treedef, [o[i] for o in outs])
        return unf(0), {"m": unf(1), "v": unf(2)}

    return Optimizer(init, update,
                     state_bytes_per_param=2 * jnp.dtype(state_dtype).itemsize)


def _trust_ratio(p, upd, eps: float = 1e-9) -> jax.Array:
    wn = jnp.linalg.norm(p.astype(jnp.float32).reshape(-1))
    un = jnp.linalg.norm(upd.reshape(-1))
    ratio = jnp.where((wn > 0) & (un > 0), wn / (un + eps), 1.0)
    return ratio


def lars(lr, momentum: float = 0.9, weight_decay: float = 1e-4,
         trust_coeff: float = 0.001, state_dtype=jnp.float32) -> Optimizer:
    """Layerwise Adaptive Rate Scaling (You et al.) for large-batch SGD."""
    def init(params):
        return {"mu": jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, state_dtype), params)}

    def update(grads, state, params, step):
        lr_t = _lr_at(lr, step)

        def one(g, mu, p):
            g = g.astype(jnp.float32) + weight_decay * p.astype(jnp.float32)
            local = trust_coeff * _trust_ratio(p, g)
            mu_new = momentum * mu.astype(jnp.float32) + local * lr_t * g
            return ((p.astype(jnp.float32) - mu_new).astype(p.dtype),
                    _cast(mu_new, state_dtype))

        flat_g, treedef = jax.tree_util.tree_flatten(grads)
        flat_m = treedef.flatten_up_to(state["mu"])
        flat_p = treedef.flatten_up_to(params)
        outs = [one(g, m, p) for g, m, p in zip(flat_g, flat_m, flat_p)]
        unf = lambda i: jax.tree_util.tree_unflatten(
            treedef, [o[i] for o in outs])
        return unf(0), {"mu": unf(1)}

    return Optimizer(init, update,
                     state_bytes_per_param=jnp.dtype(state_dtype).itemsize)


def lamb(lr, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-6,
         weight_decay: float = 0.01, state_dtype=jnp.float32) -> Optimizer:
    """LAMB (You et al.): layerwise-adaptive AdamW for large-batch training."""
    def init(params):
        z = lambda p: jnp.zeros(p.shape, state_dtype)
        return {"m": jax.tree_util.tree_map(z, params),
                "v": jax.tree_util.tree_map(z, params)}

    def update(grads, state, params, step):
        lr_t = _lr_at(lr, step)
        c1 = 1 - b1 ** (step.astype(jnp.float32) + 1)
        c2 = 1 - b2 ** (step.astype(jnp.float32) + 1)

        def one(g, m, v, p):
            m_new, v_new = _adam_moments(g, m, v, b1, b2)
            upd = (m_new / c1) / (jnp.sqrt(v_new / c2) + eps)
            upd = upd + weight_decay * p.astype(jnp.float32)
            ratio = _trust_ratio(p, upd)
            return ((p.astype(jnp.float32) - lr_t * ratio * upd).astype(p.dtype),
                    _cast(m_new, state_dtype), _cast(v_new, state_dtype))

        flat_g, treedef = jax.tree_util.tree_flatten(grads)
        flat_m = treedef.flatten_up_to(state["m"])
        flat_v = treedef.flatten_up_to(state["v"])
        flat_p = treedef.flatten_up_to(params)
        outs = [one(g, m, v, p) for g, m, v, p
                in zip(flat_g, flat_m, flat_v, flat_p)]
        unf = lambda i: jax.tree_util.tree_unflatten(
            treedef, [o[i] for o in outs])
        return unf(0), {"m": unf(1), "v": unf(2)}

    return Optimizer(init, update,
                     state_bytes_per_param=2 * jnp.dtype(state_dtype).itemsize)


OPTIMIZERS = {"sgd": sgd_momentum, "adamw": adamw, "lars": lars, "lamb": lamb}


def make_optimizer(name: str, lr, *, state_dtype=jnp.float32, **kw) -> Optimizer:
    return OPTIMIZERS[name](lr, state_dtype=state_dtype, **kw)
