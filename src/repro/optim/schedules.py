"""Learning-rate schedules (warmup + cosine/linear; large-batch friendly)."""

from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def warmup_cosine(peak: float, warmup_steps: int, total_steps: int,
                  final_frac: float = 0.1):
    def fn(step):
        step = step.astype(jnp.float32) if hasattr(step, "astype") \
            else jnp.asarray(step, jnp.float32)
        warm = peak * (step + 1) / max(warmup_steps, 1)
        t = jnp.clip((step - warmup_steps) / max(total_steps - warmup_steps, 1),
                     0.0, 1.0)
        cos = peak * (final_frac + (1 - final_frac)
                      * 0.5 * (1 + jnp.cos(jnp.pi * t)))
        return jnp.where(step < warmup_steps, warm, cos)
    return fn


def warmup_linear(peak: float, warmup_steps: int, total_steps: int):
    def fn(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak * (step + 1) / max(warmup_steps, 1)
        t = jnp.clip((step - warmup_steps) / max(total_steps - warmup_steps, 1),
                     0.0, 1.0)
        return jnp.where(step < warmup_steps, warm, peak * (1 - t))
    return fn


def linear_batch_scaled(base_lr: float, base_batch: int, batch: int):
    """Goyal et al. linear scaling rule: lr grows with the global batch --
    the optimizer-side half of the paper's large-batch scaling argument."""
    return base_lr * batch / base_batch
