"""Version-portability shim over JAX API drift.

The reproduction targets the modern (JAX >= 0.5) spelling of the sharding
APIs, but must also run on 0.4.x containers (the CI image pins 0.4.37).
The drift this papers over:

  * ``jax.make_mesh``           -- grew an ``axis_types=`` kwarg in 0.5;
                                   0.4.x only takes (axis_shapes, axis_names).
  * ``jax.sharding.AxisType``   -- does not exist before 0.5; callers that
                                   only ever pass ``AxisType.Auto`` get a
                                   sentinel enum here.
  * ``jax.shard_map``           -- promoted out of ``jax.experimental`` with
                                   a keyword-only signature, an ``axis_names``
                                   set (manual axes) and ``check_vma`` (the
                                   rename of ``check_rep``).  The 0.4.x
                                   spelling is positional with an ``auto``
                                   frozenset (the complement of the manual
                                   set) and ``check_rep``.
  * ``jax.sharding.AbstractMesh`` -- 0.4.x takes one ``shape_tuple`` of
                                   (name, size) pairs; >= 0.5 takes
                                   (axis_sizes, axis_names).
  * ``jax.set_mesh``            -- new in 0.6; on 0.4.x entering the
                                   ``Mesh`` object itself as a context
                                   manager provides the same scoping.
  * ``jax.lax.axis_size``       -- new in 0.4.38+; ``lax.psum(1, axes)``
                                   is the portable spelling (constant-folded
                                   at trace time for a static mesh).

Everything in the repo that touches these APIs goes through this module, so
a JAX upgrade is a change to exactly one file.
"""

from __future__ import annotations

import contextlib
import functools
import inspect
import re
from typing import Any, Callable, Sequence

import jax
from jax import lax
from jax.sharding import AbstractMesh, Mesh


def _parse_version(v: str) -> tuple:
    return tuple(int(x) for x in re.findall(r"\d+", v)[:3])


JAX_VERSION: tuple = _parse_version(jax.__version__)

# Supported range, enforced loosely (we shim, not hard-pin).
MIN_SUPPORTED = (0, 4, 30)


# --------------------------------------------------------------------------
# AxisType
# --------------------------------------------------------------------------

if hasattr(jax.sharding, "AxisType"):            # JAX >= 0.5
    AxisType = jax.sharding.AxisType
else:
    class AxisType:                              # sentinel for 0.4.x
        """Placeholder mirroring jax.sharding.AxisType's members.

        0.4.x meshes have no axis-type concept; ``make_mesh`` below accepts
        and drops these values, so call sites can use one spelling.
        """

        Auto = "auto"
        Explicit = "explicit"
        Manual = "manual"


_HAS_NATIVE_AXIS_TYPES = hasattr(jax.sharding, "AxisType")


# --------------------------------------------------------------------------
# Mesh construction
# --------------------------------------------------------------------------

def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str], *,
              axis_types: Sequence[Any] | None = None,
              devices=None) -> Mesh:
    """``jax.make_mesh`` across versions; ``axis_types`` dropped on 0.4.x."""
    kwargs = {}
    if devices is not None:
        kwargs["devices"] = devices
    if axis_types is not None and _HAS_NATIVE_AXIS_TYPES:
        kwargs["axis_types"] = tuple(axis_types)
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names), **kwargs)


_ABSTRACT_MESH_OLD_STYLE = (
    "shape_tuple" in inspect.signature(AbstractMesh.__init__).parameters)


def abstract_mesh(axis_shapes: Sequence[int],
                  axis_names: Sequence[str]) -> AbstractMesh:
    """``AbstractMesh`` across the (sizes, names) vs shape_tuple signatures."""
    shapes = tuple(axis_shapes)
    names = tuple(axis_names)
    if _ABSTRACT_MESH_OLD_STYLE:                 # 0.4.x: ((name, size), ...)
        return AbstractMesh(tuple(zip(names, shapes)))
    return AbstractMesh(shapes, names)


def set_mesh(mesh: Mesh):
    """Context manager scoping `mesh` as the ambient mesh.

    >= 0.6: ``jax.set_mesh``; 0.4.x: the Mesh object is itself a context
    manager with equivalent scoping semantics for our usage (jit + explicit
    NamedSharding everywhere).
    """
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    if hasattr(jax.sharding, "use_mesh"):
        return jax.sharding.use_mesh(mesh)
    return mesh                                  # Mesh.__enter__ / __exit__


# --------------------------------------------------------------------------
# shard_map
# --------------------------------------------------------------------------

_NEW_SHARD_MAP = getattr(jax, "shard_map", None)
if _NEW_SHARD_MAP is None:
    from jax.experimental.shard_map import shard_map as _OLD_SHARD_MAP
else:
    _OLD_SHARD_MAP = None


def shard_map(f: Callable | None = None, *, mesh: Mesh, in_specs, out_specs,
              axis_names: Any = None, check_vma: bool = False):
    """Modern-keyword ``shard_map`` runnable on both API generations.

    ``axis_names`` is the set of MANUAL axes (modern semantics); axes of the
    mesh not named stay auto/GSPMD. ``None`` means fully manual. On 0.4.x
    this is translated to the legacy ``auto=`` complement set and
    ``check_vma`` to ``check_rep``.
    """
    if f is None:
        return functools.partial(shard_map, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, axis_names=axis_names,
                                 check_vma=check_vma)
    if _NEW_SHARD_MAP is not None:
        kwargs = dict(mesh=mesh, in_specs=in_specs, out_specs=out_specs)
        if axis_names is not None:
            kwargs["axis_names"] = set(axis_names)
        try:
            return _NEW_SHARD_MAP(f, check_vma=check_vma, **kwargs)
        except TypeError:                        # 0.5.x: pre-rename kwarg
            return _NEW_SHARD_MAP(f, check_rep=check_vma, **kwargs)
    manual = (set(mesh.axis_names) if axis_names is None
              else set(axis_names))
    auto = frozenset(set(mesh.axis_names) - manual)
    return _OLD_SHARD_MAP(f, mesh, in_specs, out_specs,
                          check_rep=check_vma, auto=auto)


# 0.4.x XLA aborts (hard Check failure in hlo_sharding_util) when a
# ``lax.scan`` while-loop appears inside a PARTIAL-manual shard_map region
# (manual over some axes, auto/GSPMD over others). Fully-manual regions are
# fine. Callers that scan inside such regions must unroll on old JAX
# (see models.transformer / train.trainer).
PARTIAL_MANUAL_SCAN_OK = _NEW_SHARD_MAP is not None


# --------------------------------------------------------------------------
# In-manual-region helpers
# --------------------------------------------------------------------------

def maybe_scan(body: Callable, init, xs, *, unroll: bool = False):
    """``lax.scan`` with a python-unrolled fallback; ys are discarded.

    The single place implementing the scan-or-unroll idiom required inside
    partial-manual shard_map regions on 0.4.x (PARTIAL_MANUAL_SCAN_OK):
    `body(carry, xs_slice) -> (carry, _)`. Returns (final_carry, None).
    """
    if not unroll:
        carry, _ = lax.scan(body, init, xs)
        return carry, None
    n = jax.tree_util.tree_leaves(xs)[0].shape[0]
    carry = init
    for r in range(n):
        carry, _ = body(carry, jax.tree_util.tree_map(lambda x: x[r], xs))
    return carry, None


def axis_size(axes) -> int:
    """Product of manual-axis sizes, callable inside a shard_map region."""
    ax = (axes,) if isinstance(axes, str) else tuple(axes)
    if hasattr(lax, "axis_size"):
        size = 1
        for a in ax:
            size *= lax.axis_size(a)
        return size
    return lax.psum(1, ax)                       # static: folded at trace


def axis_index(axes):
    """``lax.axis_index`` (portable for str and tuple on both generations)."""
    return lax.axis_index(axes)


# --------------------------------------------------------------------------
# Compiled-executable introspection
# --------------------------------------------------------------------------

def cost_analysis(compiled) -> dict:
    """``compiled.cost_analysis()`` normalized to one flat dict.

    0.4.x returns a one-element list of per-program dicts; >= 0.5 returns
    the dict directly (and may return None for unsupported backends).
    """
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca) if ca else {}
