"""repro: "On Scale-out Deep Learning Training for Cloud and HPC" (Intel MLSL,
SysML 2018) rebuilt as a production-style JAX/TPU framework.

Layers:
  repro.core        -- the paper's contribution: C2C analysis, hybrid-parallel
                       planner, MLSL-style collectives, priority scheduler,
                       network simulator, quantized communication.
  repro.models      -- composable model zoo (dense/GQA/MLA/MoE/SSM/hybrid/
                       enc-dec/VLM backbones).
  repro.data/optim/train/serve/checkpoint -- training & serving substrate.
  repro.kernels     -- Pallas TPU kernels (block int8 quantization data path).
  repro.configs     -- assigned architectures and input shapes.
  repro.launch      -- mesh construction, multi-pod dry-run, drivers.
"""

__version__ = "0.1.0"
