"""Roofline summary: reads the dry-run artifacts (launch/dryrun.py writes
artifacts/dryrun/*.json) and emits the per-(arch x shape x mesh) roofline
terms as CSV -- the §Roofline table of EXPERIMENTS.md in benchmark form."""

from __future__ import annotations

import glob
import json
import os

from benchmarks import common
from benchmarks.common import emit

ART_DIR = os.environ.get("DRYRUN_DIR", "artifacts/dryrun")


def run():
    files = sorted(glob.glob(os.path.join(ART_DIR, "*.json")))
    if not files:
        emit("roofline/none", 0.0,
             f"no dry-run artifacts in {ART_DIR}; run "
             "`python -m repro.launch.dryrun --all --both-meshes` first")
        return
    n_ok = 0
    for f in files:
        with open(f) as fh:
            rec = json.load(fh)
        tag = os.path.basename(f)[:-5]
        if rec.get("status") == "ok":
            r = rec["roofline"]
            n_ok += 1
            emit(f"roofline/{tag}", rec.get("compile_s", 0.0) * 1e6,
                 f"dom={r['dominant']};t_compute={r['t_compute']:.3e};"
                 f"t_memory={r['t_memory']:.3e};"
                 f"t_collective={r['t_collective']:.3e};"
                 f"useful_ratio={r['useful_ratio']:.3f}")
        elif rec.get("status") == "skipped":
            emit(f"roofline/{tag}", 0.0, "skipped:" + rec["reason"][:60])
        else:
            emit(f"roofline/{tag}", 0.0, "FAILED:" + rec.get("error", "?")[:80])
    emit("roofline/summary", 0.0, f"records={len(files)};ok={n_ok}")


def main():
    common.run_with_ledger("bench_roofline", run)


if __name__ == "__main__":
    main()
