"""Collectives-API microbenchmark (the paper's lower-level interface, C7).

Times the MLSL-style collectives data path end to end on the local device
(allreduce in each wire precision, including the fuse/quantize/unfuse work
that would wrap the wire ops on TPU), and emits the MODELED mesh-scale time
for each wire format on the production pod (derived column) -- the analog of
an OSU-style latency/bandwidth table for the library.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from benchmarks.common import emit, time_fn
from repro.core import collectives, hw


def run():
    mesh = jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)

    for n in (1 << 16, 1 << 21):
        x = jax.random.normal(jax.random.PRNGKey(0), (n,), jnp.float32)
        for wire in collectives.WIRES:
            fn = jax.jit(lambda v, wire=wire: jax.shard_map(
                lambda u: collectives.allreduce(u, ("data",), wire=wire),
                mesh=mesh, in_specs=P(), out_specs=P(),
                axis_names={"data"}, check_vma=False)(v))
            us = time_fn(fn, x)
            nbytes = n * collectives.wire_bytes_per_elem(wire)
            t_pod = hw.ring_allreduce_time(nbytes, 16, hw.ICI_LINK)
            emit(f"collectives/allreduce/{wire}/n{n}", us,
                 f"modeled_pod_ring_ms={t_pod*1e3:.3f};"
                 f"wire_bytes={nbytes:.0f}")

    # reduce_scatter / all_gather path (the int8 composition's two legs)
    x = jax.random.normal(jax.random.PRNGKey(1), (1 << 18,), jnp.float32)
    for name, fn_ in (
        ("reduce_scatter",
         lambda u: collectives.reduce_scatter(u, ("data",))),
        ("all_gather", lambda u: collectives.all_gather(u, ("data",))),
    ):
        f = jax.jit(lambda v, fn_=fn_: jax.shard_map(
            fn_, mesh=mesh, in_specs=P(), out_specs=P(),
            axis_names={"data"}, check_vma=False)(v))
        us = time_fn(f, x)
        emit(f"collectives/{name}/n{1 << 18}", us, "local_1rank_path")


def main():
    run()


if __name__ == "__main__":
    main()
