"""Collectives-API microbenchmark (the paper's lower-level interface, C7).

Times the MLSL-style collectives data path end to end on the local device
(allreduce in each wire precision, including the fuse/quantize/unfuse work
that would wrap the wire ops on TPU), and emits the MODELED mesh-scale time
for each wire format on the production pod (derived column) -- the analog of
an OSU-style latency/bandwidth table for the library.

With ``--hier`` (run as a script, so the XLA flag below lands before jax is
imported) the sweep runs on 8 virtual host devices: flat vs hierarchical
allreduce on a ("node"=2, "local"=4) mesh -- wall time of each
decomposition, per-element wire bytes by level (the fabric-byte saving is
the paper's scale-out headline), and the per-level cost model's flat/hier
choice across message sizes on the canonical topologies. If jax was already
imported with fewer devices (e.g. via benchmarks/run.py), the sweep emits a
"skipped" line instead.
"""

from __future__ import annotations

import os
import sys

if __name__ == "__main__" \
        and any(f in sys.argv for f in ("--hier", "--hybrid")) \
        and "--xla_force_host_platform_device_count" not in \
        os.environ.get("XLA_FLAGS", ""):
    # must be set before jax import (SNIPPETS.md idiom)
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                               + os.environ.get("XLA_FLAGS", ""))

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from benchmarks import common
from benchmarks.common import emit, time_fn
from repro import compat
from repro.configs import registry
from repro.core import c2c, collectives, hier, hw, planner


def run():
    mesh = compat.make_mesh((1, 1), ("data", "model"),
                            axis_types=(compat.AxisType.Auto,) * 2)

    for n in (1 << 16, 1 << 21):
        x = jax.random.normal(jax.random.PRNGKey(0), (n,), jnp.float32)
        for wire in collectives.WIRES:
            fn = jax.jit(lambda v, wire=wire: compat.shard_map(
                lambda u: collectives.allreduce(u, ("data",), wire=wire),
                mesh=mesh, in_specs=P(), out_specs=P(),
                axis_names={"data"}, check_vma=False)(v))
            us = time_fn(fn, x)
            nbytes = n * collectives.wire_bytes_per_elem(wire)
            t_pod = hw.ring_allreduce_time(nbytes, 16, hw.ICI_LINK)
            emit(f"collectives/allreduce/{wire}/n{n}", us,
                 f"modeled_pod_ring_ms={t_pod*1e3:.3f};"
                 f"wire_bytes={nbytes:.0f}")

    # reduce_scatter / all_gather path (the int8 composition's two legs)
    x = jax.random.normal(jax.random.PRNGKey(1), (1 << 18,), jnp.float32)
    for name, fn_ in (
        ("reduce_scatter",
         lambda u: collectives.reduce_scatter(u, ("data",))),
        ("all_gather", lambda u: collectives.all_gather(u, ("data",))),
    ):
        f = jax.jit(lambda v, fn_=fn_: compat.shard_map(
            fn_, mesh=mesh, in_specs=P(), out_specs=P(),
            axis_names={"data"}, check_vma=False)(v))
        us = time_fn(f, x)
        emit(f"collectives/{name}/n{1 << 18}", us, "local_1rank_path")

    # executed-hybrid comm model: the C2C chooser's plan for the canonical
    # smoke transformer on a (node=2, local=4) mesh, costed against pure
    # (flat) DP on each topology. Pure analysis -- device-independent, hence
    # a STABLE ledger metric the perf gate can fail on.
    cfg = registry.get_smoke_config("yi-6b")
    batch, seq = 8, 64
    amesh = compat.abstract_mesh((2, 4), (hier.NODE_AXIS, hier.LOCAL_AXIS))
    plan = planner.plan_hybrid(cfg, amesh, batch=batch, seq=seq)
    specs = c2c.layers_from_model_config(cfg, seq)
    for topo in (hw.CLOUD_10G, hw.HPC_OPA):
        cm = planner.model_hybrid_comm(plan, specs, batch=batch,
                                       nodes=plan.dp, topo=topo)
        # the acceptance bar: executed hybrid strictly beats pure DP
        assert cm.t_hybrid < cm.t_dp_flat, (topo.name, cm)
        emit(f"collectives/hybrid_model/{topo.name}", 0.0,
             f"exposed_dp_ms={cm.t_dp_flat*1e3:.3f};"
             f"exposed_dp_hier_ms={cm.t_dp_hier*1e3:.3f};"
             f"exposed_hybrid_ms={cm.t_hybrid*1e3:.3f};"
             f"reduction_vs_dp_x={cm.reduction_vs_flat:.2f};"
             f"model_layers={len(plan.model_layer_names)}")


def run_hier():
    """Flat vs hierarchical sweep on a ("node"=2, "local"=4) factored mesh."""
    n_dev = jax.device_count()
    if n_dev < 8:
        emit("collectives/hier/skipped", 0.0,
             f"needs 8 virtual devices, have {n_dev}")
        return
    node, local = 2, 4
    mesh = compat.make_mesh((node, local), (hier.NODE_AXIS, hier.LOCAL_AXIS))
    dspec = P((hier.NODE_AXIS, hier.LOCAL_AXIS))

    configs = (
        ("flat/fp32", None, collectives.WIRE_FP32),
        ("flat/int8", None, collectives.WIRE_INT8),
        ("hier/fp32-fp32", hier.HierSpec(), None),
        ("hier/bf16-int8",
         hier.HierSpec(wire_intra=collectives.WIRE_BF16,
                       wire_inter=collectives.WIRE_INT8), None),
    )
    for n in (1 << 16, 1 << 21):
        x = jax.random.normal(jax.random.PRNGKey(0),
                              (node * local, n), jnp.float32)
        for name, spec, wire in configs:
            if spec is None:
                inner = lambda u, w=wire: collectives.allreduce(  # noqa: E731
                    u[0], (hier.NODE_AXIS, hier.LOCAL_AXIS), wire=w)
                wb = hier.flat_wire_bytes_per_elem(wire)
            else:
                inner = lambda u, s=spec: hier.hier_allreduce(  # noqa: E731
                    u[0], s)
                wb = hier.hier_wire_bytes_per_elem(spec, local, node)
            fn = jax.jit(compat.shard_map(inner, mesh=mesh, in_specs=dspec,
                                          out_specs=P()))
            us = time_fn(fn, x)
            emit(f"collectives/hier_sweep/{name}/n{n}", us,
                 f"wire_B_per_elem_total={wb.total:.3f};"
                 f"intra={wb.intra:.3f};inter={wb.inter:.3f}")

    # the per-level cost model's choice across message sizes
    for topo in (hw.CLOUD_10G, hw.HPC_OPA):
        for nbytes in (4e3, 4e5, 4e7):
            algo = planner.choose_allreduce_algo(nbytes, nodes=16, topo=topo)
            t_flat = hw.flat_allreduce_time(nbytes, 16, topo)
            t_hier = hw.hier_allreduce_time(nbytes, 16, topo)
            emit(f"collectives/hier_choice/{topo.name}/b{int(nbytes)}",
                 0.0, f"algo={algo};flat_ms={t_flat*1e3:.3f};"
                 f"hier_ms={t_hier*1e3:.3f}")


def run_hybrid():
    """Measured hybrid vs pure-DP train steps on the ("node"=2, "local"=4)
    mesh: the chooser's model-parallel layers execute tensor-parallel over
    "local" while pure DP replicates everything. Wall-clock, so the metrics
    are unstable; the gate-able modeled comparison lives in run()."""
    n_dev = jax.device_count()
    if n_dev < 8:
        emit("collectives/hybrid/skipped", 0.0,
             f"needs 8 virtual devices, have {n_dev}")
        return
    from repro.data import pipeline
    from repro.launch import mesh as mesh_lib
    from repro.models.transformer import Batch, Model
    from repro.optim import optimizers as opt_lib
    from repro.train import trainer as tr

    cfg = registry.get_smoke_config("yi-6b")
    batch, seq = 8, 32
    mesh = mesh_lib.make_hier_mesh(2, 4)
    model = Model(cfg)
    optimizer = opt_lib.make_optimizer("adamw", 1e-3)
    dcfg = pipeline.DataConfig(vocab=cfg.vocab, seq_len=seq,
                               global_batch=batch, seed=0)
    raw = next(iter(pipeline.iterate(dcfg, 1)))
    b = Batch(tokens=jnp.asarray(raw["tokens"]),
              labels=jnp.asarray(raw["labels"]))
    results = {}
    with compat.set_mesh(mesh):
        for name, plnr in (
            ("dp", planner.Planner(mesh=mesh)),
            ("hybrid", planner.make_hybrid_planner(mesh, cfg, batch=batch,
                                                   seq=seq)),
        ):
            comm = tr.CommConfig(mode="mlsl", hier=True)
            state = tr.make_train_state(model, optimizer,
                                        jax.random.PRNGKey(0))
            step = jax.jit(tr.make_train_step(model, optimizer, mesh, plnr,
                                              comm))
            us = time_fn(step, state, b, iters=3, warmup=1)
            results[name] = us
            emit(f"collectives/hybrid_step/{name}", us,
                 f"step_us={us:.0f}us", stable=False)
    emit("collectives/hybrid_step/ratio", 0.0,
         f"dp_over_hybrid={results['dp'] / max(results['hybrid'], 1e-9):.2f}x",
         stable=False)


def main():
    if "--hier" in sys.argv:
        # distinct artifact: the 8-virtual-device sweep measures a different
        # thing than the single-device run() and must not clobber its ledger
        common.run_with_ledger("bench_collectives_hier", run_hier)
    elif "--hybrid" in sys.argv:
        common.run_with_ledger("bench_collectives_hybrid", run_hybrid)
    else:
        common.run_with_ledger("bench_collectives", run)


if __name__ == "__main__":
    main()
