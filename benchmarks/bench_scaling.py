"""Paper claim #2 (Fig. 2 + TF/Horovod): 'Resnet-50 scaling on Intel Xeon
6148 and Intel Omnipath fabric using Intel Caffe and MLSL demonstrate 90%
scaling on 256 nodes', and '>93% scaling efficiency ... on 64 nodes' for the
MLSL-backed TF integration vs out-of-box Horovod-MPI.

Methodology: strong scaling at global batch 8192 (the LARS-era ImageNet
operating point) on 2S Xeon-6148 nodes; Omni-Path modeled at 4 GB/s
effective allreduce bandwidth (era-typical MPI_Allreduce on 100 Gb OPA).
The discrete-event model BRACKETS the measurement:

  * lower bound = BLOCKING policy (no overlap at all),
  * upper bound = PRIORITY policy with dedicated-core async progress
    (eta=0.7) -- MLSL's design point.

The paper's measured 90% @256 sits inside the bracket; the residual gap to
the upper bound is input pipeline/update-step/jitter overhead outside a
communication-scheduling model (EXPERIMENTS.md discusses). The Horovod
comparison runs the FIFO policy with opportunistic progress (eta=0.45) --
out-of-box MPI semantics.
"""

from __future__ import annotations

import dataclasses

from benchmarks import common
from benchmarks.common import emit, time_fn
from repro.configs import cnn_tables
from repro.core import hw, planner, simulator as sim

GLOBAL_BATCH = 8192
OPA_EFFECTIVE = dataclasses.replace(hw.OMNIPATH, bw=4e9)
MLSL_EFF = 0.7
HOROVOD_MPI_EFF = 0.45

# -- degradation scenarios (Keuper & Pfreundt 1609.06870: scaling limits
# appear where links degrade and stragglers emerge) -------------------------
FAULTS = (
    ("degraded_inter", sim.FaultSpec(inter_bw_factor=0.4)),
    ("congested_intra", sim.FaultSpec(intra_bw_factor=0.25)),
    ("straggler_1p5x", sim.FaultSpec(straggler_slowdown=1.5)),
    ("hetero_links", sim.FaultSpec(hetero_link_bw_factors=(1.0, 0.6, 0.9))),
)
# inter-fabric degradation used for the routing-crossover scenario
ROUTING_FAULT = sim.FaultSpec(inter_bw_factor=0.4)
ROUTING_TOPO = hw.CLOUD_VIRT        # the one hierarchy where flat can win
ROUTING_NODES = 16
BUCKET_SWEEP_MB = (0.25, 1.0, 4.0, 16.0, 25.0, 64.0)


def run():
    specs = cnn_tables.resnet50_layers()
    out = {}
    for p in (16, 32, 64, 128, 256):
        bs = GLOBAL_BATCH // p
        layers = sim.layers_from_specs(specs, bs, hw.XEON_6148)
        us = time_fn(lambda: sim.simulate_iteration(
            layers, p, OPA_EFFECTIVE, sim.Policy.PRIORITY_OVERLAP,
            overlap_eff=MLSL_EFF), iters=3)
        prio = sim.simulate_iteration(layers, p, OPA_EFFECTIVE,
                                      sim.Policy.PRIORITY_OVERLAP,
                                      overlap_eff=MLSL_EFF)
        blocking = sim.simulate_iteration(layers, p, OPA_EFFECTIVE,
                                          sim.Policy.BLOCKING,
                                          overlap_eff=MLSL_EFF)
        hvd = sim.simulate_iteration(layers, p, OPA_EFFECTIVE,
                                     sim.Policy.FIFO_OVERLAP,
                                     overlap_eff=HOROVOD_MPI_EFF)
        e_hi = prio.compute_time / prio.total_time
        e_lo = blocking.compute_time / blocking.total_time
        e_hvd = hvd.compute_time / hvd.total_time
        out[p] = (e_lo, e_hi, e_hvd)
        emit(f"scaling/resnet50/opa/n{p}", us,
             f"bs_per_node={bs};eff_blocking={e_lo:.3f};"
             f"eff_mlsl={e_hi:.3f};eff_horovod_mpi={e_hvd:.3f}")
    lo, hi, _ = out[256]
    emit("scaling/summary/fig2", 0.0,
         f"bracket_n256=[{lo:.3f},{hi:.3f}];paper_fig2=0.90;"
         f"in_bracket={lo <= 0.90 <= hi}")
    _, hi64, hvd64 = out[64]
    emit("scaling/summary/tf_horovod", 0.0,
         f"mlsl_eff_n64={hi64:.3f};paper_claim>0.93;"
         f"consistent={hi64 > 0.93};horovod_mpi_n64={hvd64:.3f}")
    run_faults()
    return out


def _crossover_mb(topo, fault=None):
    """Smallest swept bucket size routed FLAT (hier wins below it on
    CLOUD_VIRT-shaped hierarchies); inf when the hierarchy wins everywhere."""
    for mb in BUCKET_SWEEP_MB:
        algo = planner.choose_allreduce_algo(mb * 1e6, ROUTING_NODES, topo,
                                             fault=fault)
        if algo == planner.ALGO_FLAT:
            return mb
    return float("inf")


def run_faults():
    """Fig. 2 off the happy path: scaling efficiency under injected
    degradation, and the flat/hier routing crossover shifting when the
    inter-node fabric degrades (the Cloud-vs-HPC story made testable)."""
    specs = cnn_tables.resnet50_layers()
    for p in (64, 256):
        bs = GLOBAL_BATCH // p
        layers = sim.layers_from_specs(specs, bs, hw.XEON_6148)
        eff0 = sim.scaling_efficiency(layers, p, OPA_EFFECTIVE,
                                      overlap_eff=MLSL_EFF)
        for name, fault in FAULTS:
            eff = sim.scaling_efficiency(layers, p, OPA_EFFECTIVE,
                                         overlap_eff=MLSL_EFF, fault=fault)
            emit(f"faults/scaling/resnet50/{name}/n{p}", 0.0,
                 f"eff_healthy={eff0:.3f};eff_fault={eff:.3f};"
                 f"monotone={eff <= eff0 + 1e-9}")

    # routing under degradation: per-bucket flat-vs-hier choice across
    # message sizes, healthy vs degraded inter fabric
    for mb in BUCKET_SWEEP_MB:
        nbytes = mb * 1e6
        healthy = planner.choose_allreduce_algo(nbytes, ROUTING_NODES,
                                                ROUTING_TOPO)
        degraded = planner.choose_allreduce_algo(nbytes, ROUTING_NODES,
                                                 ROUTING_TOPO,
                                                 fault=ROUTING_FAULT)
        emit(f"faults/routing/{ROUTING_TOPO.name}/mb{mb:g}", 0.0,
             f"algo_healthy={healthy};algo_degraded={degraded};"
             f"changed={healthy != degraded}")
    x0 = _crossover_mb(ROUTING_TOPO)
    x1 = _crossover_mb(ROUTING_TOPO, fault=ROUTING_FAULT)
    emit(f"faults/routing/{ROUTING_TOPO.name}/crossover", 0.0,
         f"flat_wins_above_healthy_mb={x0:g};"
         f"flat_wins_above_degraded_mb={x1:g};"
         f"routing_changed={x0 != x1}")


def main():
    common.run_with_ledger("bench_scaling", run)


if __name__ == "__main__":
    main()
