"""Measured vs modeled compute/communication overlap of the CommEngine (C4).

The paper's runtime centerpiece is dedicated communication progress that
overlaps gradient exchange with compute (endpoint servers). The CommEngine
expresses the same thing statically: with microbatch accumulation, microbatch
k's priority-chained buckets reduce interleaved with microbatch k+1's
forward/backward (`CommConfig(overlap=True)` — repro.core.engine,
train.trainer). This benchmark runs the REAL mlsl train step on the
8-virtual-device ("node"=2, "local"=4) CPU mesh and times three variants:

  * overlap off  -- blocking baseline: each microbatch's reduction chain
                    must retire before the next microbatch computes;
  * overlap on   -- the engine's software pipeline;
  * skip_reduce  -- compute-only floor (no gradient exchange at all).

measured exposed comm(mode) = t_step(mode) - t_step(skip_reduce), and the
measured reduction is exposed(off)/exposed(on). Side by side it emits the
simulator's overlap-aware bucket-schedule prediction
(planner.estimate_overlap over the engine's own EnginePlan, costed on the
canonical CLOUD_10G hierarchy with the measured compute floor as the
per-microbatch compute time). XLA:CPU executes collectives inline on the
host's shared cores, so the measured reduction is expected well below the
modeled one: the modeled number is what a fabric with real asynchronous
progress recovers (MLSL's EP-server claim), the measured one what this host
actually overlaps — the gap itself is the paper's argument for dedicated
progress resources.

Run as a script (so the XLA device-count flag lands before jax imports):

  PYTHONPATH=src:. python benchmarks/bench_overlap.py [--smoke]

If jax was already imported with fewer devices (benchmarks/run.py), the
measured sweep emits a "skipped" line and only the modeled estimate runs.
"""

from __future__ import annotations

import os
import sys

if __name__ == "__main__" and "--xla_force_host_platform_device_count" \
        not in os.environ.get("XLA_FLAGS", ""):
    # must be set before jax import (SNIPPETS.md idiom)
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                               + os.environ.get("XLA_FLAGS", ""))

import jax
import jax.numpy as jnp

from benchmarks import common
from benchmarks.common import emit, fmt_exposed, reduction_ratio, time_fn
from repro import compat
from repro.core import hw
from repro.core import planner as planner_lib
from repro.core.planner import Planner
from repro.configs import registry
from repro.data import pipeline
from repro.launch import mesh as mesh_lib
from repro.models.transformer import Batch, Model
from repro.optim import optimizers as opt_lib
from repro.train import trainer as tr

ARCH = "yi-6b"
NODES, LOCAL = 2, 4
SEQ = 32


def _step_us(model, opt, mesh, pln, comm, batch, iters):
    """Median per-step wall time (us) of a compiled train step."""
    with compat.set_mesh(mesh):
        state = tr.make_train_state(model, opt, jax.random.PRNGKey(0))
        step = jax.jit(tr.make_train_step(model, opt, mesh, pln, comm))
        return time_fn(lambda: step(state, batch)[1]["loss"], iters=iters)


def run(smoke: bool = False):
    accums = (2,) if smoke else (2, 4)
    iters = 3 if smoke else 5
    if jax.device_count() < NODES * LOCAL:
        emit("overlap/engine", 0.0,
             f"skipped=needs {NODES * LOCAL} devices "
             f"(run as a script); have {jax.device_count()}")
        measured = False
    else:
        measured = True
        mesh = mesh_lib.make_hier_mesh(node=NODES, local=LOCAL)
        cfg = registry.get_smoke_config(ARCH)
        model = Model(cfg)
        opt = opt_lib.sgd_momentum(1e-3)
        pln = Planner(mesh=mesh)

    for acc in accums:
        base = dict(mode="mlsl", wire="fp32", accum_steps=acc)
        n_micro = acc
        if measured:
            gb = NODES * LOCAL * acc      # one sample per device-microbatch
            dcfg = pipeline.DataConfig(vocab=cfg.vocab, seq_len=SEQ,
                                       global_batch=gb)
            raw = next(iter(pipeline.iterate(dcfg, 1)))
            batch = Batch(tokens=jnp.asarray(raw["tokens"]),
                          labels=jnp.asarray(raw["labels"]))
            t_floor = _step_us(model, opt, mesh, pln,
                               tr.CommConfig(**base, skip_reduce=True),
                               batch, iters)
            t_off = _step_us(model, opt, mesh, pln,
                             tr.CommConfig(**base, overlap=False),
                             batch, iters)
            t_on = _step_us(model, opt, mesh, pln,
                            tr.CommConfig(**base, overlap=True),
                            batch, iters)
            exp_off = (t_off - t_floor) * 1e-6               # seconds
            exp_on = (t_on - t_floor) * 1e-6
            # on a loaded CPU host the comm cost can sit inside the timing
            # noise; a ratio of noise over noise would be meaningless
            noisy = exp_off <= 0 or exp_on <= 0
            measured_red = reduction_ratio(exp_off, exp_on)
            # the engine's own plan feeds the modeled estimate
            engine = tr.make_comm_engine(model, mesh, pln,
                                         tr.CommConfig(**base, overlap=True))
            micro_compute = t_floor * 1e-6 / n_micro
        else:
            # modeled-only fallback: a representative smoke-size plan
            cfg = registry.get_smoke_config(ARCH)
            model = Model(cfg)
            mesh11 = compat.make_mesh(
                (1, 1), ("data", "model"),
                axis_types=(compat.AxisType.Auto,) * 2)
            engine = tr.make_comm_engine(
                model, mesh11, Planner(mesh=mesh11),
                tr.CommConfig(mode="mlsl", accum_steps=acc, overlap=True))
            micro_compute = 5e-3

        off, on = planner_lib.estimate_overlap(
            engine.plan.buckets.buckets, engine.plan.algos, NODES,
            hw.CLOUD_10G, n_micro, micro_compute)
        modeled_red = reduction_ratio(off.exposed_comm, on.exposed_comm)
        derived = (fmt_exposed({"model_block": off.exposed_comm,
                                "model_overlap": on.exposed_comm})
                   + f";modeled_reduction={modeled_red:.2f}x"
                   + f";buckets={engine.plan.n_buckets}")
        if measured:
            measured_field = ("measured_reduction=below_noise_floor" if noisy
                              else f"measured_reduction={measured_red:.2f}x")
            derived = (f"t_floor={t_floor * 1e-3:.1f}ms;"
                       f"t_block={t_off * 1e-3:.1f}ms;"
                       f"t_overlap={t_on * 1e-3:.1f}ms;"
                       + fmt_exposed({"block": exp_off, "overlap": exp_on})
                       + f";{measured_field};" + derived)
        # in measured mode even the modeled numbers inherit the measured
        # compute floor, so the whole row is wall-clock-derived (unstable);
        # the modeled-only fallback is deterministic.
        emit(f"overlap/engine/micro{n_micro}",
             t_on if measured else 0.0, derived, stable=not measured)


def main():
    common.run_with_ledger("bench_overlap",
                           lambda: run(smoke="--smoke" in sys.argv))


if __name__ == "__main__":
    main()
