"""Paper claims #4/#5 (C1-C4): the compute-to-communication ratio analysis
and its consequences.

  1. C2C ratio is proportional to mini-batch (motivates large-batch, C3) and
     INDEPENDENT of kernel size / feature counts / stride for data-parallel
     conv layers (the Das et al. analysis the paper builds on);
  2. per-layer strategy table: what the DL Layer API picks (data / model /
     hybrid + node-group size) for conv vs FC layers of the paper's CNNs and
     for transformer blocks of the assigned archs (C2);
  3. overlap benefit: blocking vs FIFO vs priority exposed-comm across the
     batch sweep (C4).
"""

from __future__ import annotations

from benchmarks.common import emit, time_fn
from repro.configs import cnn_tables
from repro.core import c2c, hw, planner, simulator as sim


def run():
    # 1 -- proportionality + invariance
    base = c2c.conv_layer("conv", 256, 256, 3, 14, 14)
    for b in (16, 64, 256):
        r = c2c.data_parallel_ratio(base, b, 64)
        emit(f"c2c/batch{b}", 0.0, f"ratio={r:.1f}")
    r0 = c2c.data_parallel_ratio(base, 64, 64)
    variants = {
        "kernel5": c2c.conv_layer("conv", 256, 256, 5, 14, 14),
        "feat512": c2c.conv_layer("conv", 512, 512, 3, 14, 14),
        "stride2": c2c.conv_layer("conv", 256, 256, 3, 14, 14, stride=2),
    }
    for name, v in variants.items():
        r = c2c.data_parallel_ratio(v, 64, 64)
        emit(f"c2c/invariance/{name}", 0.0,
             f"ratio={r:.1f};base={r0:.1f};equal={abs(r - r0) < 1e-6}")

    # 2 -- strategy table (the DL Layer API decision, paper C2)
    p = 64
    for topo in ("resnet50", "vgg16"):
        layers = cnn_tables.TOPOLOGIES[topo]()
        report = planner.plan_report(layers, batch=2048, p=p)
        counts = {}
        fc_choice = None
        for lp in report:
            counts[lp.choice.strategy.value] = counts.get(
                lp.choice.strategy.value, 0) + 1
            if lp.kind == "fc" and fc_choice is None:
                fc_choice = lp.choice
        emit(f"c2c/strategy/{topo}", 0.0,
             f"counts={counts};first_fc={fc_choice.strategy.value}"
             f"@g{fc_choice.group_size}")

    # 3 -- overlap benefit across the batch sweep
    specs = cnn_tables.resnet50_layers()
    for bs in (16, 32, 64):
        layers = sim.layers_from_specs(specs, bs, hw.XEON_6148)
        us = time_fn(lambda: sim.simulate_iteration(
            layers, 64, hw.ETH_10G, sim.Policy.BLOCKING), iters=3)
        vals = {}
        for pol in sim.Policy:
            st = sim.simulate_iteration(layers, 64, hw.ETH_10G, pol,
                                        overlap_eff=0.7)
            vals[pol.value] = st.exposed_comm
        emit(f"overlap/resnet50/bs{bs}", us,
             ";".join(f"exposed_{k}={v*1e3:.1f}ms" for k, v in vals.items()))


def main():
    run()


if __name__ == "__main__":
    main()
