"""Shared benchmark plumbing: CSV emission, timing, exposed-comm metrics,
and the perf-ledger schema.

The perf ledger
===============
Every benchmark module writes a ``BENCH_<module>.json`` artifact (the CSV on
stdout is unchanged) so benchmark numbers persist as a *trajectory* instead
of dying in CI logs. One artifact = one `Ledger` record:

.. code-block:: json

    {
      "schema_version": 1,
      "module": "bench_scaling",
      "created_unix": 1754550000.0,
      "git_sha": "abc123...",            // null outside a git checkout
      "device_count": 8,                 // null when jax was never imported
      "jax_version": "0.4.30",
      "python_version": "3.10.14",
      "platform": "linux",
      "metrics": [
        {"name": "scaling/summary/fig2/eff_mlsl", "value": 0.93,
         "unit": "", "better": "higher", "stable": true},
        ...
      ]
    }

Metric entries:
  * ``name``   -- hierarchical, ``<emit name>/<derived key>``;
  * ``value``  -- float, or a string for categorical facts (e.g. a routing
    choice ``algo=hier``); string metrics are informational, never gated;
  * ``unit``   -- "", "us", "ms", "s", "x", "B" ... parsed off the derived
    value's suffix;
  * ``better`` -- "lower" | "higher" | null. Null means informational.
    ``scripts/perf_table.py --diff`` gates ONLY directional metrics;
  * ``stable`` -- false for wall-clock measurements (and anything derived
    from them), which jitter across hosts; the diff gate warns instead of
    failing on unstable metrics unless given an explicit ``--time-tol``.

How to add a metric: ``emit(name, us, "my_metric=1.23ms;...")`` inside a
module's ``run()`` is enough — emit() parses every ``k=v`` pair of the
derived column into the active ledger, classifying direction from the name/
unit (`classify_metric`). Pass ``stable=False`` when the values derive from
wall-clock measurement. For full control call ``current_ledger().record()``.

Modules run under ``run_with_ledger`` (their ``main()``s and
``benchmarks/run.py`` both do), which creates/writes the artifact around
``run()``; the artifact directory is ``$BENCH_DIR`` or ``artifacts/bench``.
"""

from __future__ import annotations

import dataclasses
import json
import os
import platform as _platform
import re
import subprocess
import sys
import time

SCHEMA_VERSION = 1
ARTIFACT_PREFIX = "BENCH_"
DEFAULT_BENCH_DIR = "artifacts/bench"

_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ---------------------------------------------------------------------------
# schema
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Metric:
    """One ledger entry. `value` is a float for quantitative metrics or a
    string for categorical facts (never gated)."""

    name: str
    value: object
    unit: str = ""
    better: str | None = None        # "lower" | "higher" | None (info)
    stable: bool = True

    def to_json(self) -> dict:
        return {"name": self.name, "value": self.value, "unit": self.unit,
                "better": self.better, "stable": self.stable}


_LOWER_PAT = re.compile(
    r"(^|[/_])(t_|time|exposed|latency|rmse|err|us_per_call|compile)"
    r"|_ms$|_us$|_s$|_time$|_err(or)?$")
_HIGHER_PAT = re.compile(
    r"(^|[/_])(eff|efficiency|reduction|improvement|saving|throughput|"
    r"tokens_per_sec|useful_ratio)")


def classify_metric(name: str, unit: str = "") -> str | None:
    """Default gate direction for a metric name: "lower" for time/error-like
    metrics, "higher" for efficiency/reduction-like ones, None (ungated
    informational) otherwise."""
    low = name.lower()
    if _HIGHER_PAT.search(low):
        return "higher"
    if _LOWER_PAT.search(low) or unit in ("us", "ms", "s"):
        return "lower"
    return None


def validate_ledger(rec: dict) -> None:
    """Raise ValueError if `rec` is not a schema-valid ledger record."""
    if not isinstance(rec, dict):
        raise ValueError("ledger record must be a JSON object")
    for key, typ in (("schema_version", int), ("module", str),
                     ("created_unix", (int, float)), ("metrics", list)):
        if key not in rec:
            raise ValueError(f"missing required key {key!r}")
        if not isinstance(rec[key], typ):
            raise ValueError(f"key {key!r} has type {type(rec[key]).__name__}")
    if rec["schema_version"] > SCHEMA_VERSION:
        raise ValueError(
            f"schema_version {rec['schema_version']} is newer than "
            f"supported {SCHEMA_VERSION}")
    for m in rec["metrics"]:
        if not isinstance(m, dict) or "name" not in m or "value" not in m:
            raise ValueError(f"malformed metric entry: {m!r}")
        if not isinstance(m["name"], str):
            raise ValueError(f"metric name must be a string: {m!r}")
        if not isinstance(m["value"], (int, float, str)):
            raise ValueError(f"metric value must be number or string: {m!r}")
        if m.get("better") not in ("lower", "higher", None):
            raise ValueError(f"metric better must be lower|higher|null: {m!r}")


def _git_sha() -> str | None:
    try:
        out = subprocess.run(["git", "rev-parse", "HEAD"], cwd=_REPO_ROOT,
                             capture_output=True, text=True, timeout=10)
        return out.stdout.strip() if out.returncode == 0 else None
    except Exception:                                     # noqa: BLE001
        return None


def _device_count() -> int | None:
    # Never IMPORT jax just for metadata (that would initialize a platform
    # in pure-simulator benchmarks); report only if it is already loaded.
    jax = sys.modules.get("jax")
    if jax is None:
        return None
    try:
        return int(jax.device_count())
    except Exception:                                     # noqa: BLE001
        return None


class Ledger:
    """Collects one module's metrics and writes its BENCH_<module>.json."""

    def __init__(self, module: str):
        self.module = module
        self.metrics: list = []
        self.created_unix = time.time()
        self.t_start = time.perf_counter()   # for runtime/wall_s

    def elapsed_s(self) -> float:
        """Wall seconds since this ledger was created."""
        return time.perf_counter() - self.t_start

    def record(self, name: str, value, unit: str = "",
               better: str | None = None, stable: bool = True) -> None:
        if better is None and not isinstance(value, str):
            better = classify_metric(name, unit)
        self.metrics.append(Metric(name=name, value=value, unit=unit,
                                   better=better, stable=stable))

    def to_record(self) -> dict:
        jax = sys.modules.get("jax")
        return {
            "schema_version": SCHEMA_VERSION,
            "module": self.module,
            "created_unix": self.created_unix,
            "git_sha": _git_sha(),
            "device_count": _device_count(),
            "jax_version": getattr(jax, "__version__", None),
            "python_version": _platform.python_version(),
            "platform": sys.platform,
            "metrics": [m.to_json() for m in self.metrics],
        }

    def write(self, out_dir: str | None = None) -> str:
        out_dir = out_dir or os.environ.get("BENCH_DIR", DEFAULT_BENCH_DIR)
        os.makedirs(out_dir, exist_ok=True)
        rec = self.to_record()
        validate_ledger(rec)
        path = os.path.join(out_dir, f"{ARTIFACT_PREFIX}{self.module}.json")
        with open(path, "w") as fh:
            json.dump(rec, fh, indent=1, sort_keys=True)
            fh.write("\n")
        return path


# ---------------------------------------------------------------------------
# active-ledger plumbing (emit() records into it transparently)
# ---------------------------------------------------------------------------

_ACTIVE: Ledger | None = None


def start_ledger(module: str) -> Ledger:
    global _ACTIVE
    _ACTIVE = Ledger(module)
    return _ACTIVE


def current_ledger() -> Ledger | None:
    return _ACTIVE


def finish_ledger(out_dir: str | None = None) -> str | None:
    """Write and deactivate the active ledger; returns the artifact path.

    Stamps the module's total wall runtime (``runtime/wall_s``) into the
    record first — unstable by construction, so the diff gate only ever
    warns on it. (Recorded here, not in ``write()``: a bare Ledger used as
    a container round-trips exactly what was recorded into it.)
    """
    global _ACTIVE
    led, _ACTIVE = _ACTIVE, None
    if led is None:
        return None
    led.record("runtime/wall_s", led.elapsed_s(), unit="s", better="lower",
               stable=False)
    return led.write(out_dir)


def run_with_ledger(module: str, fn, *args, out_dir: str | None = None,
                    **kw):
    """Run one benchmark module's `run()` with a ledger active, writing the
    BENCH_<module>.json artifact even if the run raises part-way (partial
    trajectories beat absent ones)."""
    start_ledger(module)
    try:
        return fn(*args, **kw)
    finally:
        path = finish_ledger(out_dir)
        if path:
            print(f"ledger: {path}", file=sys.stderr)


_NUM_RE = re.compile(r"^[+-]?(\d+\.?\d*|\.\d+)([eE][+-]?\d+)?"
                     r"(?P<unit>us|ms|s|x|B|GB)?$")


def _parse_value(raw: str):
    """'12.3ms' -> (12.3, 'ms'); 'True' -> (1.0, ''); 'hier' -> ('hier', '')."""
    if raw in ("True", "False"):
        return float(raw == "True"), ""
    if raw in ("inf", "-inf", "nan"):
        return float(raw), ""
    m = _NUM_RE.match(raw)
    if m:
        unit = m.group("unit") or ""
        return float(raw[:len(raw) - len(unit)]), unit
    return raw, ""


def emit(name: str, us_per_call: float, derived: str, *,
         stable: bool = True):
    """Print one CSV row AND record its content into the active ledger.

    The `derived` column's ``k=v`` pairs become ledger metrics named
    ``<name>/<k>`` (floats where they parse, strings otherwise; a trailing
    us/ms/s/x/B unit is split off). A positive `us_per_call` is recorded as
    ``<name>/us_per_call`` — wall-clock, hence always unstable. Pass
    ``stable=False`` when the derived values themselves depend on
    measurement (the diff gate then warns instead of failing on them).
    """
    print(f"{name},{us_per_call:.3f},{derived}")
    led = _ACTIVE
    if led is None:
        return
    if us_per_call > 0:
        led.record(f"{name}/us_per_call", float(us_per_call), unit="us",
                   better="lower", stable=False)
    for part in derived.split(";"):
        if "=" not in part:
            continue
        k, _, raw = part.partition("=")
        k, raw = k.strip(), raw.strip()
        if not k or not raw:
            continue
        val, unit = _parse_value(raw)
        is_wallclock = unit == "us" or k.endswith("_us")
        led.record(f"{name}/{k}", val, unit=unit,
                   stable=stable and not is_wallclock)


# ---------------------------------------------------------------------------
# timing + shared metric spellings
# ---------------------------------------------------------------------------

def fmt_exposed(exposed_by_key: dict) -> str:
    """The shared ``exposed_<policy>=<ms>`` metric spelling (one key per
    scheduling policy/mode), used by every overlap-family benchmark."""
    return ";".join(f"exposed_{k}={v * 1e3:.1f}ms"
                    for k, v in exposed_by_key.items())


def reduction_ratio(baseline: float, improved: float) -> float:
    """exposed-comm reduction, baseline/improved, inf-safe (the paper's
    headline metric shape: 'N.Nx reduction in exposed communication')."""
    if improved <= 1e-9:
        return float("inf") if baseline > 1e-9 else 1.0
    return baseline / improved


def time_fn(fn, *args, iters: int = 5, warmup: int = 2) -> float:
    """Median wall time per call in microseconds (CPU; jitted fns blocked)."""
    for _ in range(warmup):
        r = fn(*args)
    if warmup > 0:
        # block on the last warmup result: asynchronously dispatched warmup
        # work must retire before the first timed iteration, or it bleeds
        # into (and skews) the timed loop's median.
        _block(r)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        r = fn(*args)
        _block(r)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def _block(x):
    try:
        import jax
        jax.block_until_ready(x)
    except Exception:
        pass
