"""Shared benchmark plumbing: CSV emission + timing."""

from __future__ import annotations

import time


def emit(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.3f},{derived}")


def time_fn(fn, *args, iters: int = 5, warmup: int = 2) -> float:
    """Median wall time per call in microseconds (CPU; jitted fns blocked)."""
    for _ in range(warmup):
        r = fn(*args)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        r = fn(*args)
        _block(r)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def _block(x):
    try:
        import jax
        jax.block_until_ready(x)
    except Exception:
        pass
