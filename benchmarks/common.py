"""Shared benchmark plumbing: CSV emission, timing, exposed-comm metrics."""

from __future__ import annotations

import time


def emit(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.3f},{derived}")


def fmt_exposed(exposed_by_key: dict) -> str:
    """The shared ``exposed_<policy>=<ms>`` metric spelling (one key per
    scheduling policy/mode), used by every overlap-family benchmark."""
    return ";".join(f"exposed_{k}={v * 1e3:.1f}ms"
                    for k, v in exposed_by_key.items())


def reduction_ratio(baseline: float, improved: float) -> float:
    """exposed-comm reduction, baseline/improved, inf-safe (the paper's
    headline metric shape: 'N.Nx reduction in exposed communication')."""
    if improved <= 1e-9:
        return float("inf") if baseline > 1e-9 else 1.0
    return baseline / improved


def time_fn(fn, *args, iters: int = 5, warmup: int = 2) -> float:
    """Median wall time per call in microseconds (CPU; jitted fns blocked)."""
    for _ in range(warmup):
        r = fn(*args)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        r = fn(*args)
        _block(r)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def _block(x):
    try:
        import jax
        jax.block_until_ready(x)
    except Exception:
        pass
