"""Benchmark harness entry point: one module per paper table/figure/claim.

  bench_prioritization -- 1.8-2.2x exposed-comm reduction (Xeon+10GbE)
  bench_scaling        -- Fig. 2 ResNet-50/Omni-Path scaling + TF/Horovod
                          + fault-injected degradation scenarios
  bench_quantization   -- low-precision wire formats (volume/fidelity/kernel)
  bench_overlap        -- CommEngine overlap: measured vs modeled exposed comm
  bench_collectives    -- collectives-API microbench + modeled pod times
  bench_roofline       -- roofline terms from the dry-run artifacts
  bench_detect         -- health-monitor precision/recall on labeled
                          simulated fault episodes (gated)

Prints ``name,us_per_call,derived`` CSV, and writes one perf-ledger artifact
``BENCH_<module>.json`` per module (plus an aggregate ``BENCH_index.json``)
into ``$BENCH_DIR`` (default ``artifacts/bench``) — the persisted perf
trajectory that ``scripts/perf_table.py`` renders and diff-gates.
"""

from __future__ import annotations

import json
import os
import sys
import traceback

from benchmarks import (bench_collectives, bench_detect, bench_overlap,
                        bench_prioritization, bench_quantization,
                        bench_roofline, bench_scaling, common)

MODULES = [bench_prioritization, bench_scaling, bench_quantization,
           bench_overlap, bench_collectives, bench_roofline, bench_detect]


def main() -> None:
    out_dir = os.environ.get("BENCH_DIR", common.DEFAULT_BENCH_DIR)
    print("name,us_per_call,derived")
    failed = []
    index = {}
    for mod in MODULES:
        name = mod.__name__.rsplit(".", 1)[-1]
        common.start_ledger(name)
        status = "ok"
        try:
            mod.run()
        except Exception:                      # noqa: BLE001
            failed.append(mod.__name__)
            status = "failed"
            traceback.print_exc(file=sys.stderr)
        finally:
            led = common.current_ledger()
            n_metrics = len(led.metrics)
            runtime_s = led.elapsed_s()
            path = common.finish_ledger(out_dir)
        index[name] = {"artifact": os.path.basename(path),
                       "status": status, "n_metrics": n_metrics,
                       "runtime_s": runtime_s}
        print(f"ledger: {path} ({status}, {n_metrics} metrics, "
              f"{runtime_s:.1f}s)", file=sys.stderr)

    # aggregate: one index artifact tying the per-module ledgers of this run
    # together (same schema; module metadata lives in each artifact)
    agg = common.Ledger("index")
    for name, info in index.items():
        agg.record(f"index/{name}/n_metrics", float(info["n_metrics"]))
        agg.record(f"index/{name}/status", info["status"])
        agg.record(f"index/{name}/runtime_s", info["runtime_s"], unit="s",
                   better="lower", stable=False)
    agg.record("index/total_runtime_s",
               sum(i["runtime_s"] for i in index.values()), unit="s",
               better="lower", stable=False)
    rec = agg.to_record()
    rec["modules"] = index
    agg_path = os.path.join(out_dir, f"{common.ARTIFACT_PREFIX}index.json")
    with open(agg_path, "w") as fh:
        json.dump(rec, fh, indent=1, sort_keys=True)
        fh.write("\n")
    print(f"ledger: {agg_path} ({len(index)} modules)", file=sys.stderr)

    if failed:
        raise SystemExit(f"benchmarks failed: {failed}")


if __name__ == "__main__":
    main()
