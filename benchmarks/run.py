"""Benchmark harness entry point: one module per paper table/figure/claim.

  bench_prioritization -- 1.8-2.2x exposed-comm reduction (Xeon+10GbE)
  bench_scaling        -- Fig. 2 ResNet-50/Omni-Path scaling + TF/Horovod
  bench_quantization   -- low-precision wire formats (volume/fidelity/kernel)
  bench_overlap        -- CommEngine overlap: measured vs modeled exposed comm
  bench_collectives    -- collectives-API microbench + modeled pod times
  bench_roofline       -- roofline terms from the dry-run artifacts

Prints ``name,us_per_call,derived`` CSV.
"""

from __future__ import annotations

import sys
import traceback

from benchmarks import (bench_collectives, bench_overlap,
                        bench_prioritization, bench_quantization,
                        bench_roofline, bench_scaling)

MODULES = [bench_prioritization, bench_scaling, bench_quantization,
           bench_overlap, bench_collectives, bench_roofline]


def main() -> None:
    print("name,us_per_call,derived")
    failed = []
    for mod in MODULES:
        try:
            mod.run()
        except Exception:                      # noqa: BLE001
            failed.append(mod.__name__)
            traceback.print_exc(file=sys.stderr)
    if failed:
        raise SystemExit(f"benchmarks failed: {failed}")


if __name__ == "__main__":
    main()
