"""Paper claim #3 (low-precision communication, C6): 'the precision for
communication could be further reduced allowing for improved scaling.'

Five measurements:
  1. wire-volume reduction of the bf16 / int8(+scales) formats vs fp32
     (analytic, from the collective composition in repro.core.collectives);
  2. quantization fidelity: RMS error of the int8 block format on gradient-
     like distributions, with and without error feedback accumulation;
  3. data-path kernel cost: us/call of the (interpret-mode) Pallas block
     quantizer vs the pure-jnp oracle across bucket sizes;
  4. fused-vs-unfused HBM traffic of the int8 EF hot path (analytic, the
     hw.quant_hbm_bytes accounting the planner's cost model charges) — the
     gated headline is quant/fused_hbm_bytes_ratio;
  5. measured fused-vs-composed wall clock of the same data path (CPU jnp +
     interpret-mode pallas; unstable, machine-dependent).

``--smoke`` trims the measured sections for CI.
"""

from __future__ import annotations

import sys

import jax
import jax.numpy as jnp

from benchmarks import common
from benchmarks.common import emit, time_fn
from repro.core import collectives, hw
from repro.kernels import ops as kops


def run(smoke: bool = False):
    # 1 -- wire volume
    for wire in collectives.WIRES:
        bpe = collectives.wire_bytes_per_elem(wire)
        emit(f"quantization/wire_bytes/{wire}", 0.0,
             f"bytes_per_elem={bpe:.3f};saving_vs_fp32="
             f"{collectives.wire_bytes_per_elem('fp32') / bpe:.2f}x")
        # derived effect on a 25 MB gradient bucket over 16 ranks, 10 GbE
        nbytes = 25e6 * bpe / 4.0
        t = hw.ring_allreduce_time(nbytes, 16, hw.ETH_10G)
        emit(f"quantization/bucket_allreduce_model/{wire}", 0.0,
             f"modeled_time_ms={t*1e3:.2f}")

    # 2 -- fidelity
    key = jax.random.PRNGKey(0)
    g = jax.random.normal(key, (1 << 18,)) * 1e-3      # gradient-scale values
    q, s, meta = kops.quantize(g, backend="jnp")
    rmse = float(kops.quantization_rmse(g, backend="jnp"))
    rel = rmse / float(jnp.sqrt(jnp.mean(g * g)))
    emit("quantization/int8_rmse", 0.0,
         f"rmse={rmse:.3e};relative={rel:.4f}")
    # error feedback drives the accumulated bias to ~zero
    acc_plain = jnp.zeros_like(g)
    acc_ef = jnp.zeros_like(g)
    resid = jnp.zeros_like(g)
    for _ in range(16):
        q, s, meta = kops.quantize(g, backend="jnp")
        acc_plain = acc_plain + kops.dequantize(q, s, meta, backend="jnp")
        q, s, meta = kops.quantize(g + resid, backend="jnp")
        deq = kops.dequantize(q, s, meta, backend="jnp")
        resid = g + resid - deq
        acc_ef = acc_ef + deq
    err_plain = float(jnp.linalg.norm(acc_plain - 16 * g))
    err_ef = float(jnp.linalg.norm(acc_ef - 16 * g))
    emit("quantization/error_feedback", 0.0,
         f"accum16_err_plain={err_plain:.3e};accum16_err_ef={err_ef:.3e};"
         f"improvement={err_plain / max(err_ef, 1e-12):.1f}x")

    # 3 -- kernel cost (interpret mode on CPU; compiled on real TPU)
    for n in (1 << 16,) if smoke else (1 << 16, 1 << 20):
        x = jax.random.normal(key, (n,))
        us_jnp = time_fn(lambda x=x: kops.quantize(x, backend="jnp")[0])
        us_pal = time_fn(lambda x=x: kops.quantize(x, backend="pallas")[0])
        emit(f"quantization/kernel_n{n}", us_pal,
             f"jnp_us={us_jnp:.1f};pallas_interpret_us={us_pal:.1f}")

    # 4 -- fused-vs-unfused HBM traffic of the int8 hot path (analytic: the
    # per-element pass accounting hw.quant_hbm_bytes charges, the same term
    # planner.choose_allreduce_algo adds to both candidate routes). The
    # ratio is the PR's gated headline: the single-pass kernels must move
    # at most half the bytes of the composed passes.
    n = 1 << 20
    for ef in (False, True):
        fused_b = hw.quant_hbm_bytes(n, ef=ef, fused=True)
        unfused_b = hw.quant_hbm_bytes(n, ef=ef, fused=False)
        tag = "ef" if ef else "plain"
        emit(f"quant/hbm_bytes/{tag}", 0.0,
             f"fused_bytes_per_elem={fused_b / n:.2f}B;"
             f"unfused_bytes_per_elem={unfused_b / n:.2f}B;"
             f"ratio={fused_b / unfused_b:.4f}", stable=True)
    ratio = (hw.quant_hbm_bytes(n, ef=True, fused=True)
             / hw.quant_hbm_bytes(n, ef=True, fused=False))
    led = common.current_ledger()
    if led is not None:
        # "ratio" matches neither better-classifier pattern: record the
        # gated headline explicitly (lower is better, stable → diff-gated)
        led.record("quant/fused_hbm_bytes_ratio", float(ratio),
                   better="lower", stable=True)
    # effect on the modeled int8 fabric leg: overhead term + hier time on
    # the cloud topology the paper's scale-out argument targets
    nbytes = 25e6
    for fused in (True, False):
        t_q = hw.quant_overhead_time(nbytes, hw.CLOUD_10G, ef=True,
                                     fused=fused)
        t_h = hw.hier_allreduce_time(nbytes, 4, hw.CLOUD_10G,
                                     wire_inter="int8", ef=True,
                                     fused_quant=fused)
        emit(f"quant/modeled_hier_int8/{'fused' if fused else 'unfused'}",
             0.0, f"quant_overhead_ms={t_q*1e3:.3f};"
             f"hier_time_ms={t_h*1e3:.3f}")

    # 5 -- measured fused vs composed EF data path (wall clock; unstable)
    for n in (1 << 16,) if smoke else (1 << 16, 1 << 20):
        x = (jax.random.normal(key, (n,)) * 1e-3).astype(jnp.bfloat16)
        resid = jnp.zeros((n,))

        def fused_ef(x=x, resid=resid, backend="jnp"):
            return kops.quantize_ef(x, resid, backend=backend)[0]

        def composed_ef(x=x, resid=resid, backend="jnp"):
            y = x.astype(jnp.float32) + resid
            q, s, meta = kops.quantize(y, backend=backend)
            kops.dequantize_accumulate(q, -s, y, meta, backend=backend)
            return q

        us_f = time_fn(fused_ef)
        us_c = time_fn(composed_ef)
        emit(f"quant/ef_path_n{n}", 0.0,
             f"fused_jnp_us={us_f:.1f};composed_jnp_us={us_c:.1f}",
             stable=False)


def main():
    common.run_with_ledger("bench_quantization", run,
                           smoke="--smoke" in sys.argv)


if __name__ == "__main__":
    main()
