"""Detector precision/recall against labeled simulated fault episodes (C10).

PR 6's fault harness (simulator.FaultSpec) can produce exactly the failures
the health monitor (repro.obs.detect) must catch — so it doubles as labeled
ground truth. Each episode replays a deterministic simulated run
(simulator.generate_episode: healthy warm-up, fault onset at a known step,
2% deterministic jitter from an inline LCG — no numpy RNG, so the stream is
bit-stable across library versions) through a fresh HealthMonitor and
scores the alarms against the label:

  * correct    -- the expected alarm kind (and level, for link faults) at or
                  after the labeled onset;
  * incorrect  -- any alarm on a clean episode, a wrong kind/level, or an
                  alarm before onset (warm-up must never fire).

The headline metrics are STABLE AND GATED — the detector gets the same
regression protection the cost model has:

  detect/precision             >= 0.9 required (gated, higher-better)
  detect/recall                >= 0.9 required (gated, higher-better)
  detect/clean_false_positives == 0  required (gated, lower-better)
  detect/factor_relerr_max     gated, lower-better: worst relative error of
                               the alarm's degradation-factor estimate vs
                               the injected factor across detected episodes.

Episode notes: link-level discrimination lives in how small latency-bound
buckets and bulk volume-bound buckets drift *differently* per level, so the
intra-fault episode pins an all-hier plan on `cloud-virtio-sriov` (where
intra carries ~80% of hier volume — a strong signature); the routed plans
on that topology keep bulk flat on the healthy fabric, which is exactly why
an intra hypothesis cannot mimic an inter fault there. The no-sampling
episode checks the step_time_drift fallback (bucket replay disabled).

Pure simulator + detector — no jax needed:

  PYTHONPATH=src:. python benchmarks/bench_detect.py [--smoke]
"""

from __future__ import annotations

import sys

from benchmarks import common
from repro.core import planner as planner_lib
from repro.core import simulator as sim
from repro.obs import detect, telemetry

# synthetic gradient-bucket footprint: three bulk buckets, a mid bucket,
# and a latency-bound tail (bytes) — the shape scheduler.greedy_buckets
# produces for a transformer stack
BUCKET_BYTES = (25e6, 25e6, 25e6, 12e6, 4e6, 1e6, 0.25e6)

EpisodeCase = tuple  # (EpisodeSpec, algos_mode, expected_level)


def _episodes(smoke: bool) -> list:
    """(spec, algos_mode) cases; algos_mode "routed" uses the planner's
    per-bucket flat/hier choice on the episode topology, "hier" pins the
    all-hierarchical plan (the intra-discrimination case)."""
    F = sim.FaultSpec
    eps = [
        (sim.EpisodeSpec(name="clean", label="clean"), "routed"),
        (sim.EpisodeSpec(name="straggler_1p5x", label="straggler",
                         fault=F(straggler_slowdown=1.5), seed=2), "routed"),
        (sim.EpisodeSpec(name="degraded_inter_0p4", label="link_degraded",
                         level="inter", fault=F(inter_bw_factor=0.4),
                         seed=4), "routed"),
    ]
    if smoke:
        return eps
    eps += [
        (sim.EpisodeSpec(name="clean_hier", label="clean", seed=1), "hier"),
        (sim.EpisodeSpec(name="straggler_2x", label="straggler",
                         fault=F(straggler_slowdown=2.0), seed=3), "routed"),
        (sim.EpisodeSpec(name="degraded_inter_0p6", label="link_degraded",
                         level="inter", fault=F(inter_bw_factor=0.6),
                         seed=5), "routed"),
        (sim.EpisodeSpec(name="hetero_links", label="link_degraded",
                         level="inter",
                         fault=F(hetero_link_bw_factors=(1.0, 0.6, 0.9)),
                         seed=6), "routed"),
        (sim.EpisodeSpec(name="congested_intra", label="link_degraded",
                         level="intra", fault=F(intra_bw_factor=0.25),
                         seed=7), "hier"),
        (sim.EpisodeSpec(name="drift_nosample", label="step_time_drift",
                         fault=F(straggler_slowdown=1.8), sample_every=0,
                         seed=8), "routed"),
    ]
    return eps


def _algos(spec, mode: str) -> tuple:
    if mode == "hier":
        return tuple("hier" for _ in BUCKET_BYTES)
    topo = sim.hw.TOPOLOGIES[spec.topo_name]
    return tuple(
        planner_lib.choose_allreduce_algo(b, spec.nodes, topo)
        for b in BUCKET_BYTES)


_EXPECTED_KIND = {
    "straggler": detect.ALARM_STRAGGLER,
    "link_degraded": detect.ALARM_LINK_DEGRADED,
    "step_time_drift": detect.ALARM_STEP_DRIFT,
}


def _score(spec, alarms) -> dict:
    """Classify one episode's alarms against its label."""
    expected = _EXPECTED_KIND.get(spec.label)
    correct = []
    incorrect = []
    for a in alarms:
        ok = (expected is not None and a.kind == expected
              and a.step >= spec.onset
              and (spec.label != "link_degraded" or a.level == spec.level))
        (correct if ok else incorrect).append(a)
    return {"correct": correct, "incorrect": incorrect}


def run(smoke: bool = False):
    led = common.current_ledger()
    n_correct = n_incorrect = 0
    n_faulty = n_detected = 0
    clean_fp = 0
    relerr_max = 0.0

    for spec, mode in _episodes(smoke):
        algos = _algos(spec, mode)
        events = sim.generate_episode(spec, BUCKET_BYTES, algos)
        telemetry.validate_telemetry(events)   # the schema contract, always
        mon = detect.HealthMonitor(
            bucket_bytes=BUCKET_BYTES, algos=algos, nodes=spec.nodes,
            topo=spec.topo_name)
        mon.replay(events)
        sc = _score(spec, mon.alarms)
        correct, incorrect = sc["correct"], sc["incorrect"]
        n_correct += len(correct)
        n_incorrect += len(incorrect)
        if spec.label == "clean":
            clean_fp += len(mon.alarms)
        else:
            n_faulty += 1
            if correct:
                n_detected += 1
                est = correct[0].factor
                true = spec.true_factor
                relerr = abs(est - true) / max(abs(true), 1e-9)
                relerr_max = max(relerr_max, relerr)

        first = correct[0] if correct else (
            mon.alarms[0] if mon.alarms else None)
        reroute = ""
        if correct:
            reroute = mon.reroute(correct[0]).summary()
        fields = [
            f"label={spec.label or 'clean'}",
            f"expected={_EXPECTED_KIND.get(spec.label, 'none')}",
            f"alarm_kind={first.kind if first else 'none'}",
            f"alarm_level={first.level if first and first.level else '-'}",
            f"first_alarm_step={first.step if first else -1}",
            f"onset={spec.onset}",
            f"factor_true={spec.true_factor:.2f}",
            f"factor_est={first.factor:.3f}" if first else "factor_est=-1",
            f"n_alarms={len(mon.alarms)}",
        ]
        if reroute and led is not None:
            led.record(f"detect/ep/{spec.name}/reroute", reroute)
        common.emit(f"detect/ep/{spec.name}", 0.0, ";".join(fields))

    precision = (n_correct / (n_correct + n_incorrect)
                 if (n_correct + n_incorrect) else 1.0)
    recall = n_detected / n_faulty if n_faulty else 1.0
    if led is not None:
        led.record("detect/precision", precision, better="higher",
                   stable=True)
        led.record("detect/recall", recall, better="higher", stable=True)
        led.record("detect/clean_false_positives", float(clean_fp),
                   better="lower", stable=True)
        led.record("detect/factor_relerr_max", relerr_max, better="lower",
                   stable=True)
    print(f"detect/summary,0.000,precision={precision:.3f};"
          f"recall={recall:.3f};clean_false_positives={clean_fp};"
          f"factor_relerr_max={relerr_max:.3f}")
    assert precision >= 0.9, f"precision {precision:.3f} < 0.9"
    assert recall >= 0.9, f"recall {recall:.3f} < 0.9"
    assert clean_fp == 0, f"{clean_fp} clean-episode false positives"
    return {"precision": precision, "recall": recall, "clean_fp": clean_fp}


def main():
    common.run_with_ledger("bench_detect",
                           lambda: run(smoke="--smoke" in sys.argv))


if __name__ == "__main__":
    main()
