"""Paper claim #1 (message prioritization): 'This optimization resulted in
1.8x to 2.2x reduction in exposed communication time for standard topologies
such as Resnet-50, VGG-16, and Googlenet on Intel Xeon Gold 6148 processors
and 10Gbps Ethernet.'

Reproduced with the discrete-event simulator (repro.core.simulator) on the
same three topologies, node class (2-socket Xeon 6148) and fabric (10 GbE):
FIFO-overlap (asynchronous reduction in issue order -- MPI semantics, the
paper's baseline) vs MLSL's preemptive priority policy.

Calibration: per-node mini-batch 32 (48 for GoogleNet) -- the strong-scaling
regime the paper targets, where communication is comparable to compute --
and overlap efficiency eta=0.7 (transfers overlapped with compute run at 70%
of wire rate; imperfect asynchronous progress is exactly the host-resource
effect MLSL's dedicated progress cores address).

Expected outcome (EXPERIMENTS.md §Benchmarks): ResNet-50 1.9x and GoogleNet
2.1x at their 32-node operating points, inside the paper's band; VGG-16
2.4-2.9x, ABOVE the band, because 84% of its gradient bytes sit in three FC
layers whose bulk transfers our zero-cost preemption rescues perfectly,
while MLSL's real chunked preemption saturates near 2.2x. A refuted-then-
explained hypothesis -- see EXPERIMENTS.md.
"""

from __future__ import annotations

from benchmarks import common
from benchmarks.common import emit, fmt_exposed, reduction_ratio, time_fn
from repro.configs import cnn_tables
from repro.core import hw, simulator as sim

BATCH_PER_NODE = {"resnet50": 32, "vgg16": 32, "googlenet": 48}
OVERLAP_EFF = 0.7
NODES = (16, 32, 64)
OPERATING_POINT = {"resnet50": 32, "vgg16": 64, "googlenet": 32}


def run():
    results = {}
    for topo, layer_fn in cnn_tables.TOPOLOGIES.items():
        specs = layer_fn()
        layers = sim.layers_from_specs(specs, BATCH_PER_NODE[topo],
                                       hw.XEON_6148)
        for p in NODES:
            us = time_fn(lambda: sim.simulate_iteration(
                layers, p, hw.ETH_10G, sim.Policy.PRIORITY_OVERLAP,
                overlap_eff=OVERLAP_EFF), iters=3)
            fifo = sim.simulate_iteration(layers, p, hw.ETH_10G,
                                          sim.Policy.FIFO_OVERLAP,
                                          overlap_eff=OVERLAP_EFF)
            prio = sim.simulate_iteration(layers, p, hw.ETH_10G,
                                          sim.Policy.PRIORITY_OVERLAP,
                                          overlap_eff=OVERLAP_EFF)
            blocking = sim.simulate_iteration(layers, p, hw.ETH_10G,
                                              sim.Policy.BLOCKING,
                                              overlap_eff=OVERLAP_EFF)
            red = reduction_ratio(fifo.exposed_comm, prio.exposed_comm)
            results[(topo, p)] = red
            emit(f"prioritization/{topo}/n{p}", us,
                 fmt_exposed({"fifo": fifo.exposed_comm,
                              "prio": prio.exposed_comm,
                              "blocking": blocking.exposed_comm})
                 + f";reduction={red:.2f}x")
    op = [results[(t, OPERATING_POINT[t])] for t in cnn_tables.TOPOLOGIES]
    emit("prioritization/summary", 0.0,
         f"operating_point_reductions="
         + ";".join(f"{t}={results[(t, OPERATING_POINT[t])]:.2f}x"
                    for t in cnn_tables.TOPOLOGIES)
         + f";paper_claim=1.8x..2.2x")
    return results


def main():
    common.run_with_ledger("bench_prioritization", run)


if __name__ == "__main__":
    main()
