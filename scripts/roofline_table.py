"""Render the §Dry-run / §Roofline markdown tables from artifacts/dryrun."""

from __future__ import annotations

import glob
import json
import os
import sys

ORDER_SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
ORDER_ARCHS = ["yi-6b", "llava-next-mistral-7b", "minicpm3-4b", "arctic-480b",
               "chatglm3-6b", "mamba2-2.7b", "recurrentgemma-2b",
               "grok-1-314b", "whisper-small", "deepseek-7b"]


def fmt_t(v):
    if v >= 1:
        return f"{v:.2f}s"
    if v >= 1e-3:
        return f"{v*1e3:.1f}ms"
    return f"{v*1e6:.0f}us"


def fmt_b(v):
    for unit, div in (("GB", 1e9), ("MB", 1e6), ("KB", 1e3)):
        if v >= div:
            return f"{v/div:.2f}{unit}"
    return f"{v:.0f}B"


def load(art_dir):
    recs = {}
    for f in glob.glob(os.path.join(art_dir, "*.json")):
        base = os.path.basename(f)[:-5]
        parts = base.split("__")
        if len(parts) != 3:
            continue                      # variant runs handled separately
        arch, shape, mesh = parts
        with open(f) as fh:
            recs[(arch, shape, mesh)] = json.load(fh)
    return recs


def roofline_table(recs, mesh="pod16x16"):
    rows = ["| arch | shape | dominant | t_compute | t_memory | t_collective"
            " | wire/chip | useful (6ND/HLO) | fit/chip |",
            "|---|---|---|---|---|---|---|---|---|"]
    for arch in ORDER_ARCHS:
        for shape in ORDER_SHAPES:
            r = recs.get((arch, shape, mesh))
            if r is None:
                continue
            if r["status"] == "skipped":
                rows.append(f"| {arch} | {shape} | *skipped* |  |  |  |  |  "
                            f"| {r['reason'][:40]} |")
                continue
            rf = r["roofline"]
            mem = r["memory"]
            per_chip = mem["argument_bytes"] + mem["temp_bytes"]
            fit = "ok" if per_chip < 16e9 else f"OVER ({fmt_b(per_chip)})"
            rows.append(
                f"| {arch} | {shape} | **{rf['dominant']}** |"
                f" {fmt_t(rf['t_compute'])} | {fmt_t(rf['t_memory'])} |"
                f" {fmt_t(rf['t_collective'])} | {fmt_b(rf['wire_bytes'])} |"
                f" {rf['useful_ratio']:.2f} | {fit} |")
    return "\n".join(rows)


def dryrun_table(recs):
    rows = ["| arch | shape | 16x16 | 2x16x16 | compile(s) | args/chip |"
            " temp/chip |", "|---|---|---|---|---|---|---|"]
    for arch in ORDER_ARCHS:
        for shape in ORDER_SHAPES:
            r1 = recs.get((arch, shape, "pod16x16"))
            r2 = recs.get((arch, shape, "pod2x16x16"))
            if r1 is None and r2 is None:
                continue
            s1 = r1["status"] if r1 else "-"
            s2 = r2["status"] if r2 else "-"
            if s1 == "ok":
                m = r1["memory"]
                rows.append(f"| {arch} | {shape} | ok | {s2} |"
                            f" {r1['compile_s']:.1f} |"
                            f" {fmt_b(m['argument_bytes'])} |"
                            f" {fmt_b(m['temp_bytes'])} |")
            else:
                rows.append(f"| {arch} | {shape} | {s1} | {s2} |  |  |  |")
    return "\n".join(rows)


def summarize(recs):
    ok = sum(1 for r in recs.values() if r["status"] == "ok")
    sk = sum(1 for r in recs.values() if r["status"] == "skipped")
    fl = [k for k, r in recs.items() if r["status"] == "failed"]
    return ok, sk, fl


if __name__ == "__main__":
    art = sys.argv[1] if len(sys.argv) > 1 else "artifacts/dryrun"
    recs = load(art)
    ok, sk, fl = summarize(recs)
    print(f"records: ok={ok} skipped={sk} failed={fl}\n")
    print("## Dry-run matrix\n")
    print(dryrun_table(recs))
    print("\n## Roofline (single pod 16x16)\n")
    print(roofline_table(recs))
