"""Render §Perf iteration comparisons from dry-run artifacts (tagged runs)."""

from __future__ import annotations

import glob
import json
import os
import sys


def load_all(art="artifacts/dryrun"):
    out = {}
    for f in glob.glob(os.path.join(art, "*.json")):
        r = json.load(open(f))
        if r.get("status") != "ok":
            continue
        out[os.path.basename(f)[:-5]] = r
    return out


def row(recs, tag, label):
    r = recs.get(tag)
    if r is None:
        return f"| {label} | (missing) |  |  |  |  |"
    rf = r["roofline"]
    return (f"| {label} | {rf['dominant']} | {rf['t_compute']:.3f} |"
            f" {rf['t_memory']:.3f} | {rf['t_collective']:.3f} |"
            f" {rf['wire_bytes']/1e9:.1f} |")


HEADER = ("| variant | dominant | t_compute (s) | t_memory (s) |"
          " t_collective (s) | wire GB/chip |\n|---|---|---|---|---|---|")

GROUPS = {
    "arctic-480b x train_4k": [
        ("arctic-480b__train_4k__pod16x16", "baseline (paper-faithful)"),
        ("arctic-480b__train_4k__pod16x16__ep", "+ EP all-to-all MoE"),
        ("arctic-480b__train_4k__pod16x16__ep-wg8-a16",
         "+ int8 weight gathers, accum 16"),
        ("arctic-480b__train_4k__pod16x16__ep-wg8-a4",
         "+ int8 weight gathers, accum 4 (best)"),
    ],
    "minicpm3-4b x prefill_32k": [
        ("minicpm3-4b__prefill_32k__pod16x16", "baseline (paper-faithful)"),
        ("minicpm3-4b__prefill_32k__pod16x16__kc1024",
         "+ chunked attention (1024)"),
        ("minicpm3-4b__prefill_32k__pod16x16__kc2048",
         "chunk 2048 (refuted: worse)"),
    ],
    "yi-6b x train_4k": [
        ("yi-6b__train_4k__pod16x16", "baseline (paper-faithful)"),
        ("yi-6b__train_4k__pod16x16__kc1024", "+ chunked attention (1024)"),
        ("yi-6b__train_4k__pod16x16__kc1024-a8", "+ accum 8"),
        ("yi-6b__train_4k__pod16x16__dp-a8-kc1024",
         "node-group=1 (DP/ZeRO-3) (refuted: collectives blow up)"),
    ],
}


if __name__ == "__main__":
    recs = load_all(sys.argv[1] if len(sys.argv) > 1 else "artifacts/dryrun")
    for name, rows in GROUPS.items():
        print(f"### {name}\n\n{HEADER}")
        for tag, label in rows:
            print(row(recs, tag, label))
        print()
