"""Perf-ledger renderer and regression gate.

Default mode renders a markdown table from the ``BENCH_<module>.json``
artifacts a benchmark run wrote (``benchmarks/run.py`` / any module's
``main()``; see benchmarks/common.py for the schema):

  PYTHONPATH=src:. python scripts/perf_table.py [LEDGER_DIR]

Diff mode compares two ledger directories and exits non-zero when a gated
metric regresses beyond tolerance:

  PYTHONPATH=src:. python scripts/perf_table.py --diff OLD_DIR NEW_DIR \
      [--tol 0.01] [--time-tol T] [--warn-only]

Gating rules:
  * metrics with ``better`` = lower/higher and ``stable`` = true (model-
    derived, deterministic) are gated at ``--tol`` relative tolerance;
  * ``stable`` = false metrics (wall-clock-derived: us_per_call, measured
    reductions) WARN only, unless ``--time-tol`` supplies an explicit
    tolerance for them -- cross-host timing noise must not flake CI;
  * string metrics (e.g. routing choices) warn on change, never gate;
  * metrics that disappear between OLD and NEW warn, never gate.

The legacy dry-run table (tagged roofline comparisons) is kept behind
``--dryrun [ART_DIR]``.
"""

from __future__ import annotations

import argparse
import glob
import json
import os
import sys

_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if _REPO_ROOT not in sys.path:          # `from benchmarks import common`
    sys.path.insert(0, _REPO_ROOT)

from benchmarks import common  # noqa: E402


# --------------------------------------------------------------------------
# loading
# --------------------------------------------------------------------------

def load_all(art="artifacts/dryrun", pattern="*.json"):
    """Load every JSON record in a directory; skip (and report) corrupt
    files instead of crashing, and never leak file handles."""
    out = {}
    for f in sorted(glob.glob(os.path.join(art, pattern))):
        try:
            with open(f) as fh:
                r = json.load(fh)
        except (OSError, json.JSONDecodeError) as e:
            print(f"perf_table: skipping {f}: {e}", file=sys.stderr)
            continue
        if not isinstance(r, dict) or r.get("status", "ok") != "ok":
            continue
        out[os.path.basename(f)[:-5]] = r
    return out


def load_ledgers(ledger_dir):
    """{module: validated ledger record} from BENCH_*.json in a directory."""
    out = {}
    recs = load_all(ledger_dir, pattern=common.ARTIFACT_PREFIX + "*.json")
    for name, rec in sorted(recs.items()):
        try:
            common.validate_ledger(rec)
        except ValueError as e:
            print(f"perf_table: skipping {name}: {e}", file=sys.stderr)
            continue
        out[rec["module"]] = rec
    return out


def _metrics(rec):
    """{name: metric-entry} for one ledger record."""
    return {m["name"]: m for m in rec["metrics"]}


# --------------------------------------------------------------------------
# render
# --------------------------------------------------------------------------

_TABLE_HEADER = ("| metric | value | unit | better | stable |\n"
                 "|---|---:|---|---|---|")


def _fmt_value(v):
    if isinstance(v, str):
        return v
    if v != v or v in (float("inf"), float("-inf")):
        return str(v)
    if v == int(v) and abs(v) < 1e15:
        return str(int(v))
    return f"{v:.6g}"


_BUCKET_COLS = ("elems", "route", "wire", "intra_B", "inter_B", "total_B",
                "t_model_us", "t_measured_us")
_BUCKET_HEADER = ("| bucket | " + " | ".join(_BUCKET_COLS) + " |\n"
                  "|---|" + "---:|" * len(_BUCKET_COLS))


def _comm_stats_buckets(metrics):
    """{bucket_key: {field: value}} for ``comm_stats/<bucket>/<field>``
    metric names (per-bucket ``bNN`` groups plus the ``total`` row)."""
    buckets = {}
    for m in metrics:
        parts = m["name"].split("/")
        if len(parts) != 3 or parts[0] != "comm_stats":
            continue
        buckets.setdefault(parts[1], {})[parts[2]] = m["value"]
    return buckets


def render_comm_stats(metrics):
    """Per-bucket markdown table for a module's ``comm_stats/*`` entries
    (the MLSL-style wire-stats ledger repro.obs.stats writes). Purely a
    presentation regrouping — these metrics are warn-only by construction
    (informational or unstable), so the diff gate never trips on them."""
    buckets = _comm_stats_buckets(metrics)
    if not buckets:
        return []
    lines = ["#### comm_stats per bucket\n", _BUCKET_HEADER]
    total = buckets.pop("total", None)
    for key in sorted(buckets):
        row_vals = [_fmt_value(buckets[key].get(c, "")) for c in _BUCKET_COLS]
        lines.append(f"| {key} | " + " | ".join(row_vals) + " |")
    if total is not None:
        row_vals = [_fmt_value(total.get(c, "")) for c in _BUCKET_COLS]
        lines.append("| **total** | " + " | ".join(row_vals) + " |")
    lines.append("")
    return lines


_EP_COLS = ("label", "expected", "alarm_kind", "alarm_level",
            "first_alarm_step", "onset", "factor_true", "factor_est",
            "n_alarms", "reroute")
_EP_HEADER = ("| episode | " + " | ".join(_EP_COLS) + " |\n"
              "|---|" + "---|" * len(_EP_COLS))


def _detect_episodes(metrics):
    """{episode: {field: value}} for ``detect/ep/<episode>/<field>`` metric
    names (the per-episode rows bench_detect writes)."""
    eps = {}
    for m in metrics:
        parts = m["name"].split("/")
        if len(parts) != 4 or parts[0] != "detect" or parts[1] != "ep":
            continue
        eps.setdefault(parts[2], {})[parts[3]] = m["value"]
    return eps


def render_detect_episodes(metrics):
    """Per-episode alarm table for a module's ``detect/ep/*`` entries
    (bench_detect's labeled fault replays). Presentation regrouping only —
    the gated headline metrics (detect/precision etc.) stay in the flat
    table and the diff machinery is untouched."""
    eps = _detect_episodes(metrics)
    if not eps:
        return []
    lines = ["#### detect episodes\n", _EP_HEADER]
    for key in sorted(eps):
        row_vals = [_fmt_value(eps[key].get(c, "")) for c in _EP_COLS]
        lines.append(f"| {key} | " + " | ".join(row_vals) + " |")
    lines.append("")
    return lines


def render(ledgers):
    lines = []
    for module, rec in sorted(ledgers.items()):
        sha = (rec.get("git_sha") or "")[:12]
        lines.append(f"### {module}"
                     + (f"  (`{sha}`)" if sha else "") + "\n")
        # comm_stats/<bucket>/<field> and detect/ep/<episode>/<field>
        # entries regroup into their own tables; everything else renders as
        # the flat metric listing
        grouped = {f"comm_stats/{b}/{f}"
                   for b, fields in _comm_stats_buckets(
                       rec["metrics"]).items() for f in fields}
        grouped |= {f"detect/ep/{e}/{f}"
                    for e, fields in _detect_episodes(
                        rec["metrics"]).items() for f in fields}
        flat = [m for m in rec["metrics"] if m["name"] not in grouped]
        if flat:
            lines.append(_TABLE_HEADER)
            for m in flat:
                lines.append(
                    f"| {m['name']} | {_fmt_value(m['value'])} |"
                    f" {m.get('unit') or ''} | {m.get('better') or ''} |"
                    f" {'yes' if m.get('stable', True) else 'no'} |")
            lines.append("")
        lines.extend(render_comm_stats(rec["metrics"]))
        lines.extend(render_detect_episodes(rec["metrics"]))
    return "\n".join(lines)


# --------------------------------------------------------------------------
# diff / gate
# --------------------------------------------------------------------------

def diff_metric(old, new, tol, *, atol=1e-12):
    """Classify one old/new metric pair.

    Returns (kind, detail) where kind is one of:
      "ok"         -- within tolerance (or an ungated info metric)
      "improved"   -- moved in the good direction beyond tolerance
      "regressed"  -- moved in the bad direction beyond tolerance
      "changed"    -- string metric whose value changed (warn-only)
    """
    ov, nv = old["value"], new["value"]
    if isinstance(ov, str) or isinstance(nv, str):
        if ov != nv:
            return "changed", f"{ov!r} -> {nv!r}"
        return "ok", ""
    better = new.get("better") or old.get("better")
    if better not in ("lower", "higher"):
        return "ok", ""
    span = max(abs(ov), atol)
    delta = (nv - ov) / span
    detail = f"{ov:.6g} -> {nv:.6g} ({delta:+.2%})"
    if better == "lower":
        if nv > ov + span * tol + atol:
            return "regressed", detail
        if nv < ov - span * tol - atol:
            return "improved", detail
    else:
        if nv < ov - span * tol - atol:
            return "regressed", detail
        if nv > ov + span * tol + atol:
            return "improved", detail
    return "ok", ""


def diff_ledgers(old_ledgers, new_ledgers, *, tol=0.01, time_tol=None):
    """Compare two {module: record} maps.

    Returns (regressions, warnings, improvements, n_compared) where each of
    the first three is a list of human-readable strings. ``regressions`` is
    the gated set: stable directional metrics beyond ``tol``, plus unstable
    ones beyond ``time_tol`` when that was given.
    """
    regressions, warnings, improvements = [], [], []
    n_compared = 0
    for module in sorted(old_ledgers):
        if module not in new_ledgers:
            warnings.append(f"{module}: module missing from new ledger")
            continue
        om, nm = _metrics(old_ledgers[module]), _metrics(new_ledgers[module])
        for name in om:
            if name not in nm:
                warnings.append(f"{module}:{name}: missing from new ledger")
                continue
            stable = (nm[name].get("stable", True)
                      and om[name].get("stable", True))
            use_tol = tol if stable else time_tol
            kind, detail = diff_metric(om[name], nm[name],
                                       use_tol if use_tol is not None
                                       else tol)
            n_compared += 1
            line = f"{module}:{name}: {detail}"
            if kind == "regressed":
                if stable or time_tol is not None:
                    regressions.append(line)
                else:
                    warnings.append(line + " [unstable, warn-only]")
            elif kind == "changed":
                warnings.append(line + " [value changed]")
            elif kind == "improved":
                improvements.append(line)
    return regressions, warnings, improvements, n_compared


def run_diff(old_dir, new_dir, *, tol, time_tol, warn_only):
    old = load_ledgers(old_dir)
    new = load_ledgers(new_dir)
    if not old:
        print(f"perf_table: no valid ledgers in {old_dir}", file=sys.stderr)
        return 2
    if not new:
        print(f"perf_table: no valid ledgers in {new_dir}", file=sys.stderr)
        return 2
    regressions, warnings, improvements, n = diff_ledgers(
        old, new, tol=tol, time_tol=time_tol)
    print(f"perf diff: {old_dir} -> {new_dir}  "
          f"({n} metrics compared, tol={tol:g}"
          + (f", time_tol={time_tol:g}" if time_tol is not None else "")
          + ")")
    for line in improvements:
        print(f"  IMPROVED  {line}")
    for line in warnings:
        print(f"  WARN      {line}")
    for line in regressions:
        print(f"  REGRESSED {line}")
    if regressions:
        verdict = "FAIL" if not warn_only else "WARN (gate disabled)"
        print(f"perf diff: {len(regressions)} regression(s) -> {verdict}")
        return 0 if warn_only else 1
    print(f"perf diff: clean ({len(warnings)} warning(s), "
          f"{len(improvements)} improvement(s))")
    return 0


# --------------------------------------------------------------------------
# legacy dry-run table
# --------------------------------------------------------------------------

def row(recs, tag, label):
    r = recs.get(tag)
    if r is None:
        return f"| {label} | (missing) |  |  |  |  |"
    rf = r["roofline"]
    return (f"| {label} | {rf['dominant']} | {rf['t_compute']:.3f} |"
            f" {rf['t_memory']:.3f} | {rf['t_collective']:.3f} |"
            f" {rf['wire_bytes']/1e9:.1f} |")


HEADER = ("| variant | dominant | t_compute (s) | t_memory (s) |"
          " t_collective (s) | wire GB/chip |\n|---|---|---|---|---|---|")

GROUPS = {
    "arctic-480b x train_4k": [
        ("arctic-480b__train_4k__pod16x16", "baseline (paper-faithful)"),
        ("arctic-480b__train_4k__pod16x16__ep", "+ EP all-to-all MoE"),
        ("arctic-480b__train_4k__pod16x16__ep-wg8-a16",
         "+ int8 weight gathers, accum 16"),
        ("arctic-480b__train_4k__pod16x16__ep-wg8-a4",
         "+ int8 weight gathers, accum 4 (best)"),
    ],
    "minicpm3-4b x prefill_32k": [
        ("minicpm3-4b__prefill_32k__pod16x16", "baseline (paper-faithful)"),
        ("minicpm3-4b__prefill_32k__pod16x16__kc1024",
         "+ chunked attention (1024)"),
        ("minicpm3-4b__prefill_32k__pod16x16__kc2048",
         "chunk 2048 (refuted: worse)"),
    ],
    "yi-6b x train_4k": [
        ("yi-6b__train_4k__pod16x16", "baseline (paper-faithful)"),
        ("yi-6b__train_4k__pod16x16__kc1024", "+ chunked attention (1024)"),
        ("yi-6b__train_4k__pod16x16__kc1024-a8", "+ accum 8"),
        ("yi-6b__train_4k__pod16x16__dp-a8-kc1024",
         "node-group=1 (DP/ZeRO-3) (refuted: collectives blow up)"),
    ],
}


def run_dryrun(art_dir):
    recs = load_all(art_dir)
    for name, rows in GROUPS.items():
        print(f"### {name}\n\n{HEADER}")
        for tag, label in rows:
            print(row(recs, tag, label))
        print()
    return 0


# --------------------------------------------------------------------------

def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("ledger_dir", nargs="?", default=common.DEFAULT_BENCH_DIR,
                    help="ledger directory to render (default: %(default)s)")
    ap.add_argument("--diff", nargs=2, metavar=("OLD", "NEW"),
                    help="diff two ledger directories; non-zero exit on "
                         "regression")
    ap.add_argument("--tol", type=float, default=0.01,
                    help="relative tolerance for stable metrics "
                         "(default: %(default)s)")
    ap.add_argument("--time-tol", type=float, default=None,
                    help="tolerance for wall-clock (stable=false) metrics; "
                         "omit to keep them warn-only")
    ap.add_argument("--warn-only", action="store_true",
                    help="report regressions but exit 0")
    ap.add_argument("--dryrun", nargs="?", const="artifacts/dryrun",
                    metavar="ART_DIR",
                    help="legacy mode: render the tagged dry-run roofline "
                         "table from ART_DIR")
    args = ap.parse_args(argv)

    if args.dryrun is not None:
        return run_dryrun(args.dryrun)
    if args.diff is not None:
        return run_diff(args.diff[0], args.diff[1], tol=args.tol,
                        time_tol=args.time_tol, warn_only=args.warn_only)

    ledgers = load_ledgers(args.ledger_dir)
    if not ledgers:
        print(f"perf_table: no valid ledgers in {args.ledger_dir} "
              "(run `PYTHONPATH=src:. python benchmarks/run.py` first)",
              file=sys.stderr)
        return 2
    print(render(ledgers))
    return 0


if __name__ == "__main__":
    sys.exit(main())
